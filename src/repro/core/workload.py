"""Workload-graph builder: ArchConfig -> operation/tensor dependency graph for
the TRAPTI Stage-I simulator.

Follows the paper's conventions (Sec. IV-A):
  * one full forward (prefill) pass at sequence length M,
  * 8-bit quantized operands throughout,
  * positional-encoding ops omitted (element-wise, immaterial to SRAM trends),
  * `subops` decomposes large matmuls along the row (M) dimension so they can
    be scheduled across the systolic arrays (paper uses subops=4).

The builder is family-aware: dense/GQA attention (the paper's two workloads),
MoE, SSD (mamba2), RG-LRU, encoder-decoder and VLM-prefix graphs all lower to
the same op vocabulary {matmul, softmax, norm, elementwise}, which is what
makes the paper's Stage II applicable to every assigned architecture.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ArchConfig


@dataclass
class Tensor:
    tid: int
    name: str
    size: int                      # bytes
    kind: str                      # weight | activation | kv | score
    producer: Optional[int]        # op id; None => resident in DRAM (weights/inputs)
    consumers: List[int] = field(default_factory=list)


@dataclass
class Op:
    oid: int
    name: str
    op_type: str                   # matmul | softmax | norm | elementwise
    inputs: List[int]              # tensor ids
    output: int                    # tensor id
    macs: int = 0                  # multiply-accumulates (matmul)
    vector_ops: int = 0            # element ops (softmax/norm/elementwise)
    # matmul geometry (rows, contraction, cols) for SA-tiling time model
    mnk: Tuple[int, int, int] = (0, 0, 0)
    layer: int = -1
    tag: str = ""                  # coarse op class for Fig-6 style breakdowns


@dataclass
class WorkloadGraph:
    name: str
    ops: Dict[int, Op] = field(default_factory=dict)
    tensors: Dict[int, Tensor] = field(default_factory=dict)

    # ----------------------------------------------------------- builders
    def add_tensor(self, name: str, size: int, kind: str,
                   producer: Optional[int] = None) -> int:
        tid = len(self.tensors)
        self.tensors[tid] = Tensor(tid, name, int(size), kind, producer)
        return tid

    def add_op(self, name: str, op_type: str, inputs: List[int],
               out_name: str, out_size: int, out_kind: str = "activation",
               macs: int = 0, vector_ops: int = 0,
               mnk: Tuple[int, int, int] = (0, 0, 0), layer: int = -1,
               tag: str = "") -> Tuple[int, int]:
        oid = len(self.ops)
        out = self.add_tensor(out_name, out_size, out_kind, producer=oid)
        self.ops[oid] = Op(oid, name, op_type, list(inputs), out, int(macs),
                           int(vector_ops), mnk, layer, tag or op_type)
        for t in inputs:
            self.tensors[t].consumers.append(oid)
        return oid, out

    # ------------------------------------------------------------- stats
    def total_macs(self) -> int:
        return sum(op.macs for op in self.ops.values())

    def total_weight_bytes(self) -> int:
        return sum(t.size for t in self.tensors.values() if t.kind == "weight")


# ---------------------------------------------------------------------------
# Dense / GQA decoder-layer graph (the paper's workloads)
# ---------------------------------------------------------------------------

def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


class _LayerBuilder:
    """Helper carrying common dims while emitting one layer's ops."""

    def __init__(self, g: WorkloadGraph, cfg: ArchConfig, M: int, subops: int,
                 byte: int, layer: int):
        self.g, self.cfg, self.M, self.subops = g, cfg, M, subops
        self.b = byte
        self.L = layer

    def weight(self, name: str, size: int) -> int:
        return self.g.add_tensor(f"L{self.L}.{name}", size * self.b, "weight")

    def matmul_rowsplit(self, name: str, x: int, w: int, rows: int, k: int,
                        cols: int, out_kind: str = "activation",
                        tag: str = "") -> List[int]:
        """Row-partitioned matmul (subops chunks along `rows`)."""
        outs = []
        n = self.subops
        chunk = _ceil_div(rows, n)
        for i in range(n):
            r = min(chunk, rows - i * chunk)
            if r <= 0:
                break
            _, out = self.g.add_op(
                f"L{self.L}.{name}.s{i}", "matmul", [x, w],
                f"L{self.L}.{name}.out{i}", r * cols * self.b, out_kind,
                macs=r * k * cols, mnk=(r, k, cols), layer=self.L,
                tag=tag or name)
            outs.append(out)
        return outs

    def vector(self, name: str, inputs: List[int], out_size: int,
               ops_per_el: int, op_type: str = "elementwise",
               out_kind: str = "activation", tag: str = "") -> int:
        _, out = self.g.add_op(
            f"L{self.L}.{name}", op_type, inputs,
            f"L{self.L}.{name}.out", out_size * self.b, out_kind,
            vector_ops=(out_size * ops_per_el), layer=self.L,
            tag=tag or name)
        return out


def _attention_ops(lb: _LayerBuilder, x: int, kind: str = "full") -> int:
    """Emit attention ops; returns output tensor id. x: (M, D) activation.

    Sub-op decomposition follows the paper's `subops` setting: projections and
    the output projection are split along the head dimension into weight
    *slices* (so weight slabs stream through SRAM instead of co-residing),
    and scores/AV are grouped by query heads aligned to their shared KV head
    (GQA-aware).
    """
    g, cfg, M, b, L = lb.g, lb.cfg, lb.M, lb.b, lb.L
    D, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    n = lb.subops

    # effective kv context per query for local/chunked variants
    if kind in ("local", "chunked") and cfg.local_window:
        ctx = min(cfg.local_window, M)
    else:
        ctx = M

    # query-head groups, contiguous, aligned to the GQA kv mapping
    per = _ceil_div(H, n)
    head_groups: List[Tuple[int, int]] = []       # (start_head, n_heads)
    s = 0
    while s < H:
        h = min(per, H - s)
        head_groups.append((s, h))
        s += h
    q_per_kv = max(1, H // max(K, 1))

    # KV slices: one per kv head group (at most `n` slices)
    n_kv = min(K, n)
    kv_per = _ceil_div(K, n_kv)
    kv_groups: List[Tuple[int, int]] = []
    s = 0
    while s < K:
        h = min(kv_per, K - s)
        kv_groups.append((s, h))
        s += h

    # --- projections: one sliced matmul per group ----------------------------
    q_slices = []
    for i, (hs, h) in enumerate(head_groups):
        wq = lb.weight(f"Wq.s{i}", D * h * hd)
        _, qo = g.add_op(
            f"L{L}.attn.q.s{i}", "matmul", [x, wq],
            f"L{L}.attn.q.out{i}", M * h * hd * b, "activation",
            macs=M * D * h * hd, mnk=(M, D, h * hd), layer=L, tag="attn.proj")
        q_slices.append(qo)
    k_slices, v_slices = [], []
    for i, (ks, kh) in enumerate(kv_groups):
        wk = lb.weight(f"Wk.s{i}", D * kh * hd)
        wv = lb.weight(f"Wv.s{i}", D * kh * hd)
        _, ko = g.add_op(
            f"L{L}.attn.k.s{i}", "matmul", [x, wk],
            f"L{L}.attn.k.out{i}", M * kh * hd * b, "kv",
            macs=M * D * kh * hd, mnk=(M, D, kh * hd), layer=L,
            tag="attn.proj")
        _, vo = g.add_op(
            f"L{L}.attn.v.s{i}", "matmul", [x, wv],
            f"L{L}.attn.v.out{i}", M * kh * hd * b, "kv",
            macs=M * D * kh * hd, mnk=(M, D, kh * hd), layer=L,
            tag="attn.proj")
        k_slices.append(ko)
        v_slices.append(vo)

    def kv_deps(hs: int, h: int) -> List[int]:
        """kv slice indices covering query heads [hs, hs+h)."""
        lo = (hs // q_per_kv) // kv_per
        hi = ((hs + h - 1) // q_per_kv) // kv_per
        return list(range(lo, min(hi, len(kv_groups) - 1) + 1))

    # --- scores / softmax / AV per head group ---------------------------------
    out_partials = []
    for i, (hs, h) in enumerate(head_groups):
        deps = kv_deps(hs, h)
        _, sc = g.add_op(
            f"L{L}.attn.qk.g{i}", "matmul",
            [q_slices[i]] + [k_slices[j] for j in deps],
            f"L{L}.attn.scores{i}", h * M * ctx * b, "score",
            macs=h * M * hd * ctx, mnk=(M, hd, ctx), layer=L, tag="attn.qk")
        sm = lb.vector(f"attn.softmax.g{i}", [sc], h * M * ctx, 5,
                       op_type="softmax", out_kind="score",
                       tag="attn.softmax")
        _, av = g.add_op(
            f"L{L}.attn.av.g{i}", "matmul",
            [sm] + [v_slices[j] for j in deps],
            f"L{L}.attn.ctx{i}", h * M * hd * b, "activation",
            macs=h * M * ctx * hd, mnk=(M, ctx, hd), layer=L, tag="attn.av")
        # output projection slice: rows of Wo for this head group -> partial sum
        wo = lb.weight(f"Wo.s{i}", h * hd * D)
        _, po = g.add_op(
            f"L{L}.attn.out.s{i}", "matmul", [av, wo],
            f"L{L}.attn.out.part{i}", M * D * b, "activation",
            macs=M * h * hd * D, mnk=(M, h * hd, D), layer=L, tag="attn.out")
        out_partials.append(po)

    res = lb.vector("attn.residual", [x] + out_partials, M * cfg.d_model,
                    1 + len(out_partials), tag="residual")
    return res


def _ffn_ops(lb: _LayerBuilder, x: int, d_ff: int, ffn_kind: str,
             tokens: Optional[int] = None, tag: str = "ffn") -> int:
    """Column-sliced FFN: each sub-op computes a d_ff/n slice with its own
    weight slabs, and the down-projection accumulates partial sums — weight
    slices stream through SRAM one slice at a time."""
    g, cfg, b, L = lb.g, lb.cfg, lb.b, lb.L
    M = tokens if tokens is not None else lb.M
    D = cfg.d_model
    n = lb.subops
    chunk = _ceil_div(d_ff, n)
    partials = []
    i = 0
    off = 0
    while off < d_ff:
        f = min(chunk, d_ff - off)
        if ffn_kind in ("swiglu", "geglu"):
            wg = lb.weight(f"{tag}.Wg.s{i}", D * f)
            wu = lb.weight(f"{tag}.Wu.s{i}", D * f)
            wd = lb.weight(f"{tag}.Wd.s{i}", f * D)
            _, gate = g.add_op(
                f"L{L}.{tag}.gate.s{i}", "matmul", [x, wg],
                f"L{L}.{tag}.gate.out{i}", M * f * b, "activation",
                macs=M * D * f, mnk=(M, D, f), layer=L, tag=tag)
            _, up = g.add_op(
                f"L{L}.{tag}.up.s{i}", "matmul", [x, wu],
                f"L{L}.{tag}.up.out{i}", M * f * b, "activation",
                macs=M * D * f, mnk=(M, D, f), layer=L, tag=tag)
            glu = lb.vector(f"{tag}.glu.s{i}", [gate, up], M * f, 2, tag=tag)
            _, down = g.add_op(
                f"L{L}.{tag}.down.s{i}", "matmul", [glu, wd],
                f"L{L}.{tag}.down.part{i}", M * D * b, "activation",
                macs=M * f * D, mnk=(M, f, D), layer=L, tag=tag)
        else:
            wu = lb.weight(f"{tag}.Wu.s{i}", D * f)
            wd = lb.weight(f"{tag}.Wd.s{i}", f * D)
            _, up = g.add_op(
                f"L{L}.{tag}.up.s{i}", "matmul", [x, wu],
                f"L{L}.{tag}.up.out{i}", M * f * b, "activation",
                macs=M * D * f, mnk=(M, D, f), layer=L, tag=tag)
            act = lb.vector(f"{tag}.act.s{i}", [up], M * f, 2, tag=tag)
            _, down = g.add_op(
                f"L{L}.{tag}.down.s{i}", "matmul", [act, wd],
                f"L{L}.{tag}.down.part{i}", M * D * b, "activation",
                macs=M * f * D, mnk=(M, f, D), layer=L, tag=tag)
        partials.append(down)
        off += f
        i += 1
    res = lb.vector(f"{tag}.residual", [x] + partials, M * D,
                    1 + len(partials), tag="residual")
    return res


def _moe_ops(lb: _LayerBuilder, x: int) -> int:
    """Token-choice MoE: router + top_k active expert FFNs on M*k/E tokens."""
    g, cfg, M, b, L = lb.g, lb.cfg, lb.M, lb.b, lb.L
    m = cfg.moe
    D = cfg.d_model
    wr = lb.weight("moe.Wr", D * m.num_experts)
    _, probs = g.add_op(
        f"L{L}.moe.router", "matmul", [x, wr],
        f"L{L}.moe.probs", M * m.num_experts * b, "activation",
        macs=M * D * m.num_experts, mnk=(M, D, m.num_experts), layer=L,
        tag="moe.router")
    sel = lb.vector("moe.topk", [probs], M * m.top_k, 8, tag="moe.router")

    # Average load: per expert, tokens_e = M*top_k/E; we emit one FFN per
    # *active-expert slice* aggregated into `subops` groups to bound op count.
    tokens_active = M * m.top_k
    groups = min(m.num_experts, lb.subops * 2)
    tok_per_group = _ceil_div(tokens_active, groups)
    outs = []
    for e in range(groups):
        t = min(tok_per_group, tokens_active - e * tok_per_group)
        if t <= 0:
            break
        sub = _LayerBuilder(g, cfg, t, 1, b, L)
        sub_x = lb.vector(f"moe.dispatch.e{e}", [x, sel], t * D, 1,
                          tag="moe.dispatch")
        out = _ffn_ops(sub, sub_x, m.d_ff_expert, cfg.ffn_kind, tokens=t,
                       tag=f"moe.exp{e}")
        outs.append(out)
    comb = lb.vector("moe.combine", outs, M * D, 2, tag="moe.combine")
    if m.shared_expert:
        sh = _ffn_ops(lb, x, m.d_ff_expert, cfg.ffn_kind, tag="moe.shared")
        comb = lb.vector("moe.shared_add", [comb, sh], M * D, 1,
                         tag="moe.combine")
    return comb


def _ssm_ops(lb: _LayerBuilder, x: int) -> int:
    """Mamba-2 SSD block: projections + conv + chunked scan ops."""
    g, cfg, M, b, L = lb.g, lb.cfg, lb.M, lb.b, lb.L
    s = cfg.ssm
    D = cfg.d_model
    di = s.d_inner(D)
    N = s.state_dim
    H = s.num_heads(D)
    Q = s.chunk_size

    wz = lb.weight("ssm.Wz", D * di)
    wx = lb.weight("ssm.Wx", D * di)
    wB = lb.weight("ssm.WB", D * N)
    wC = lb.weight("ssm.WC", D * N)
    z = lb.matmul_rowsplit("ssm.z", x, wz, M, D, di, tag="ssm.proj")
    xs = lb.matmul_rowsplit("ssm.x", x, wx, M, D, di, tag="ssm.proj")
    Bs = lb.matmul_rowsplit("ssm.B", x, wB, M, D, N, tag="ssm.proj")
    Cs = lb.matmul_rowsplit("ssm.C", x, wC, M, D, N, tag="ssm.proj")
    conv = lb.vector("ssm.conv", xs + Bs + Cs, M * (di + 2 * N),
                     2 * s.conv_width, tag="ssm.conv")

    nc = _ceil_div(M, Q)
    # intra-chunk quadratic term: per chunk (Q,N)x(N,Q) + (Q,Q)x(Q,P*H)
    _, intra = g.add_op(
        f"L{L}.ssm.intra", "matmul", [conv],
        f"L{L}.ssm.intra.out", M * di * b, "activation",
        macs=nc * (Q * N * Q + Q * Q * di), mnk=(M, Q, di), layer=L,
        tag="ssm.scan")
    # inter-chunk state passing: nc x (H,P,N) updates + C-contraction
    _, inter = g.add_op(
        f"L{L}.ssm.inter", "matmul", [conv, intra],
        f"L{L}.ssm.inter.out", M * di * b, "activation",
        macs=nc * (di * N) + M * di * N, mnk=(M, N, di), layer=L,
        tag="ssm.scan")
    gate = lb.vector("ssm.gate", [inter] + z, M * di, 4, tag="ssm.gate")
    wo = lb.weight("ssm.Wo", di * D)
    out = lb.matmul_rowsplit("ssm.out", gate, wo, M, di, D, tag="ssm.out")
    res = lb.vector("ssm.residual", [x] + out, M * D, 1, tag="residual")
    return res


def _rglru_ops(lb: _LayerBuilder, x: int) -> int:
    g, cfg, M, b, L = lb.g, lb.cfg, lb.M, lb.b, lb.L
    w = cfg.rglru.lru_width(cfg.d_model)
    D = cfg.d_model
    wb = lb.weight("rglru.Wb", D * w)
    wr = lb.weight("rglru.Wr", D * w)
    wa = lb.weight("rglru.Wa", w * w)
    wi = lb.weight("rglru.Wi", w * w)
    wo = lb.weight("rglru.Wo", w * D)
    br = lb.matmul_rowsplit("rglru.branch", x, wb, M, D, w, tag="rglru.proj")
    u = lb.matmul_rowsplit("rglru.rec", x, wr, M, D, w, tag="rglru.proj")
    conv = lb.vector("rglru.conv", u, M * w, 2 * cfg.rglru.conv_width,
                     tag="rglru.conv")
    ga = lb.matmul_rowsplit("rglru.gate_a", conv, wa, M, w, w, tag="rglru.gates")
    gi = lb.matmul_rowsplit("rglru.gate_i", conv, wi, M, w, w, tag="rglru.gates")
    scan = lb.vector("rglru.scan", ga + gi + [conv], M * w, 6, tag="rglru.scan")
    gated = lb.vector("rglru.mul", [scan] + br, M * w, 1, tag="rglru.gate")
    out = lb.matmul_rowsplit("rglru.out", gated, wo, M, w, D, tag="rglru.out")
    return lb.vector("rglru.residual", [x] + out, M * D, 1, tag="residual")


# ---------------------------------------------------------------------------
# Full-model graphs
# ---------------------------------------------------------------------------

def build_graph(cfg: ArchConfig, M: int = 2048, subops: int = 4,
                byte: int = 1, include_head: bool = False) -> WorkloadGraph:
    """Workload graph for one forward pass at sequence length M.

    Matches the paper's setup: int8 operands (byte=1), positional ops omitted,
    LM head omitted by default (the paper's MAC totals exclude it).
    """
    g = WorkloadGraph(name=f"{cfg.name}@M{M}")
    D = cfg.d_model
    b = byte

    # token embeddings arrive from DRAM (gather, negligible MACs)
    x = g.add_tensor("embed.out", M * D * b, "activation")

    n_pfx = cfg.frontend.num_prefix_tokens if cfg.frontend is not None else 0
    if n_pfx:
        # projector matmul for the stub modality prefix
        lb0 = _LayerBuilder(g, cfg, n_pfx, subops, b, -1)
        wp = lb0.weight("projector.W", D * D)
        pfx = g.add_tensor("prefix.embeds", n_pfx * D * b, "activation")
        _, proj = g.add_op("projector", "matmul", [pfx, wp], "projector.out",
                           n_pfx * D * b, "activation", macs=n_pfx * D * D,
                           mnk=(n_pfx, D, D), layer=-1, tag="frontend")
        _, x = g.add_op("prefix.concat", "elementwise", [x, proj],
                        "embed.full", M * D * b, "activation",
                        vector_ops=M * D, layer=-1, tag="frontend")

    def decoder_layer(x: int, kind: str, L: int) -> int:
        lb = _LayerBuilder(g, cfg, M, subops, b, L)
        x = lb.vector("norm1", [x], M * D, 4, op_type="norm", tag="norm")
        if kind in ("full", "local", "chunked"):
            x = _attention_ops(lb, x, kind)
            x2 = lb.vector("norm2", [x], M * D, 4, op_type="norm", tag="norm")
            if cfg.moe is not None:
                return _moe_ops(_LayerBuilder(g, cfg, M, subops, b, L), x2)
            return _ffn_ops(lb, x2, cfg.d_ff, cfg.ffn_kind)
        if kind == "ssm":
            return _ssm_ops(lb, x)
        if kind == "rglru":
            x = _rglru_ops(lb, x)
            lb2 = _LayerBuilder(g, cfg, M, subops, b, L)
            x2 = lb2.vector("norm2", [x], M * D, 4, op_type="norm", tag="norm")
            return _ffn_ops(lb2, x2, cfg.d_ff, cfg.ffn_kind)
        raise ValueError(kind)

    if cfg.is_encdec:
        # encoder stack (non-causal full attention) then decoder with cross
        for L, kind in enumerate(["full"] * cfg.encoder_layers):
            x = decoder_layer(x, kind, L)
        mem = x
        y = g.add_tensor("dec.embed.out", M * D * b, "activation")
        for L in range(cfg.num_layers):
            LL = cfg.encoder_layers + L
            lb = _LayerBuilder(g, cfg, M, subops, b, LL)
            y = lb.vector("norm1", [y], M * D, 4, op_type="norm", tag="norm")
            y = _attention_ops(lb, y, "full")
            # cross attention reads the encoder memory
            lbc = _LayerBuilder(g, cfg, M, subops, b, LL)
            yc = lbc.vector("norm_c", [y, mem], M * D, 4, op_type="norm",
                            tag="norm")
            y = _attention_ops(lbc, yc, "full")
            lb2 = _LayerBuilder(g, cfg, M, subops, b, LL)
            y2 = lb2.vector("norm2", [y], M * D, 4, op_type="norm", tag="norm")
            y = _ffn_ops(lb2, y2, cfg.d_ff, cfg.ffn_kind)
        x = y
    else:
        for L, kind in enumerate(cfg.layer_kinds()):
            x = decoder_layer(x, kind, L)

    lbf = _LayerBuilder(g, cfg, M, subops, b, cfg.num_layers)
    x = lbf.vector("final_norm", [x], M * D, 4, op_type="norm", tag="norm")
    if include_head:
        wh = g.add_tensor("head.W", D * cfg.vocab_size * b, "weight")
        g.add_op("lm_head", "matmul", [x, wh], "logits",
                 M * cfg.vocab_size * b, "activation",
                 macs=M * D * cfg.vocab_size, mnk=(M, D, cfg.vocab_size),
                 layer=cfg.num_layers, tag="head")
    return g


def decode_probe_contexts(start_ctx: int, steps: int,
                          n_probes: int = 3) -> List[int]:
    """Probe context lengths for the PSS decode fast path.

    Returns the endpoints of the decode horizon [start_ctx,
    start_ctx + steps - 1] plus evenly-spaced interior probes — the context
    lengths at which the exact DES is run so the per-step delta-event
    pattern can be affinely tiled (and its affinity *validated* at the
    interior probes) across the whole horizon. With `steps <= n_probes`
    every step is a probe and PSS degenerates to the exact path."""
    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    if n_probes < 2:
        raise ValueError(f"n_probes must be >= 2, got {n_probes}")
    last = start_ctx + steps - 1
    if steps <= n_probes:
        return list(range(start_ctx, last + 1))
    return sorted({start_ctx + (i * (steps - 1)) // (n_probes - 1)
                   for i in range(n_probes)})


def build_decode_graph(cfg: ArchConfig, context_len: int = 2048,
                       batch: int = 64, subops: int = 4,
                       byte: int = 1) -> WorkloadGraph:
    """One batched decode step: projections/FFN over `batch` token rows plus
    attention over a `context_len` KV cache per layer. This is the regime of
    the paper's Fig. 1 — KV-cache traffic (proportional to kv heads) dominates,
    which is where MHA vs GQA separates.
    """
    g = WorkloadGraph(name=f"{cfg.name}@decode{context_len}x{batch}")
    D = cfg.d_model
    b = byte
    Bt = batch                       # token rows this step
    x = g.add_tensor("decode.in", Bt * D * b, "activation")

    for L, kind in enumerate(cfg.layer_kinds()):
        lb = _LayerBuilder(g, cfg, Bt, min(subops, 2), b, L)
        x = lb.vector("norm1", [x], Bt * D, 4, op_type="norm", tag="norm")
        if kind in ("full", "local", "chunked"):
            H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
            ctx = context_len
            if kind in ("local", "chunked") and cfg.local_window:
                ctx = min(cfg.local_window, context_len)
            wq = lb.weight("Wq", D * H * hd)
            wk = lb.weight("Wk", D * K * hd)
            wv = lb.weight("Wv", D * K * hd)
            wo = lb.weight("Wo", H * hd * D)
            _, q = g.add_op(f"L{L}.dec.q", "matmul", [x, wq], f"L{L}.dec.q.o",
                            Bt * H * hd * b, "activation",
                            macs=Bt * D * H * hd, mnk=(Bt, D, H * hd),
                            layer=L, tag="attn.proj")
            _, kk = g.add_op(f"L{L}.dec.k", "matmul", [x, wk], f"L{L}.dec.k.o",
                             Bt * K * hd * b, "kv", macs=Bt * D * K * hd,
                             mnk=(Bt, D, K * hd), layer=L, tag="attn.proj")
            _, vv = g.add_op(f"L{L}.dec.v", "matmul", [x, wv], f"L{L}.dec.v.o",
                             Bt * K * hd * b, "kv", macs=Bt * D * K * hd,
                             mnk=(Bt, D, K * hd), layer=L, tag="attn.proj")
            # the KV cache for this layer: batch x ctx x kv-dim, streamed in
            kcache = g.add_tensor(f"L{L}.kcache", Bt * ctx * K * hd * b, "kv")
            vcache = g.add_tensor(f"L{L}.vcache", Bt * ctx * K * hd * b, "kv")
            _, sc = g.add_op(
                f"L{L}.dec.qk", "matmul", [q, kk, kcache],
                f"L{L}.dec.scores", Bt * H * ctx * b, "score",
                macs=Bt * H * hd * ctx, mnk=(Bt * H, hd, ctx), layer=L,
                tag="attn.qk")
            sm = lb.vector("dec.softmax", [sc], Bt * H * ctx, 5,
                           op_type="softmax", out_kind="score",
                           tag="attn.softmax")
            _, av = g.add_op(
                f"L{L}.dec.av", "matmul", [sm, vv, vcache],
                f"L{L}.dec.ctx", Bt * H * hd * b, "activation",
                macs=Bt * H * ctx * hd, mnk=(Bt * H, ctx, hd), layer=L,
                tag="attn.av")
            _, o = g.add_op(
                f"L{L}.dec.out", "matmul", [av, wo], f"L{L}.dec.out.o",
                Bt * D * b, "activation", macs=Bt * H * hd * D,
                mnk=(Bt, H * hd, D), layer=L, tag="attn.out")
            x = lb.vector("dec.res1", [x, o], Bt * D, 2, tag="residual")
            x2 = lb.vector("norm2", [x], Bt * D, 4, op_type="norm", tag="norm")
            if cfg.moe is not None:
                x = _moe_ops(_LayerBuilder(g, cfg, Bt, 1, b, L), x2)
            else:
                x = _ffn_ops(lb, x2, cfg.d_ff, cfg.ffn_kind)
        elif kind == "ssm":
            x = _ssm_ops(lb, x)
        elif kind == "rglru":
            x = _rglru_ops(lb, x)
            lb2 = _LayerBuilder(g, cfg, Bt, 1, b, L)
            x2 = lb2.vector("norm2", [x], Bt * D, 4, op_type="norm",
                            tag="norm")
            x = _ffn_ops(lb2, x2, cfg.d_ff, cfg.ffn_kind)
    lbf = _LayerBuilder(g, cfg, Bt, 1, b, cfg.num_layers)
    g_out = lbf.vector("final_norm", [x], Bt * D, 4, op_type="norm",
                       tag="norm")
    return g
