"""Power-gating policies + Eq. (2)-(5) energy model (TRAPTI Stage II).

    E_tot = E_dyn + E_leak + E_sw                                  (2)
    E_dyn = N_R * E_R + N_W * E_W                                  (3)
    E_leak ~= sum_k P_leak_bank * B_on(k) * dt_k                   (4)
    E_sw  = N_sw * E_sw_bank                                       (5)

Policies:
  * "none"         — no gating; all B banks leak for the whole run.
  * "aggressive"   — alpha = 1.0 packing; gate every idle-eligible interval
                     that passes the break-even criterion.
  * "conservative" — alpha = 0.9 headroom; additionally skip idle intervals
                     shorter than `min_gate_multiple` x break-even (avoids
                     thrashing and wake-up latency exposure).
  * "drowsy"       — three-state ON/DROWSY/OFF: idle intervals >= the gate
                     threshold fully gate as usual, shorter ones drop to a
                     retention voltage (`drowsy_fraction` of full leakage,
                     `drowsy_switch_fraction` of a full switch per run) —
                     the Flautner-style policy `sensitivity.evaluate_drowsy`
                     models, expressed as a `Policy` so the streaming
                     `obs.energy.BankEnergyMeter` can run it online.

`evaluate` is the *scalar reference*: one candidate at a time, per-bank
Python loops. Sweeps, campaigns and CLIs run on the batched engine
(`core.candidates.evaluate_candidates`), which is property-tested against
this function and evaluates the whole (C, B, alpha, policy) grid in one
vectorized call.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional

import numpy as np

from repro.core.banking import bank_activity, bank_on_matrix, idle_runs
from repro.core.cacti import SramCharacterization, characterize


@dataclass(frozen=True)
class Policy:
    name: str
    alpha: float
    gate: bool
    min_gate_multiple: float = 1.0      # x break-even time
    # three-state retention knobs: idle runs *below* the gate threshold leak
    # at `drowsy_fraction` of full power (1.0 = stay fully ON, the classic
    # two-state policies) and cost `drowsy_switch_fraction` of a full
    # power-gate switch per run (0.0 = no transition). The defaults make the
    # new terms exact no-ops, so pre-existing policies are bit-identical.
    drowsy_fraction: float = 1.0
    drowsy_switch_fraction: float = 0.0

    @staticmethod
    def none(alpha: float = 1.0) -> "Policy":
        return Policy("none", alpha, gate=False)

    @staticmethod
    def aggressive() -> "Policy":
        return Policy("aggressive", 1.0, gate=True, min_gate_multiple=1.0)

    @staticmethod
    def conservative(alpha: float = 0.9) -> "Policy":
        return Policy("conservative", alpha, gate=True, min_gate_multiple=5.0)

    @staticmethod
    def drowsy(alpha: float = 0.9, off_multiple: float = 1.0) -> "Policy":
        from repro.core.sensitivity import (DROWSY_LEAK_FRACTION,
                                            DROWSY_SWITCH_FRACTION)
        return Policy("drowsy", alpha, gate=True,
                      min_gate_multiple=off_multiple,
                      drowsy_fraction=DROWSY_LEAK_FRACTION,
                      drowsy_switch_fraction=DROWSY_SWITCH_FRACTION)

    @staticmethod
    def by_name(name: str, alpha: Optional[float] = None) -> "Policy":
        """Resolve a CLI policy spelling; `alpha` overrides the default."""
        table = {"none": Policy.none(), "aggressive": Policy.aggressive(),
                 "conservative": Policy.conservative(),
                 "drowsy": Policy.drowsy()}
        if name not in table:
            raise ValueError(f"unknown policy {name!r}; "
                             f"choose from {sorted(table)}")
        p = table[name]
        if alpha is not None and alpha != p.alpha:
            p = replace(p, alpha=alpha)
        return p


@dataclass
class GatingResult:
    policy: str
    alpha: float
    capacity: int
    banks: int
    e_dyn: float
    e_leak: float
    e_sw: float
    n_transitions: int
    gated_bank_seconds: float
    total_bank_seconds: float
    area_mm2: float
    # three-state extras (zero for the classic two-state policies)
    drowsy_bank_seconds: float = 0.0
    n_drowsy: int = 0

    @property
    def e_total(self) -> float:
        return self.e_dyn + self.e_leak + self.e_sw


def evaluate(durations: np.ndarray, occupancy: np.ndarray, *,
             capacity: int, banks: int, policy: Policy,
             n_reads: int, n_writes: int,
             char: Optional[SramCharacterization] = None) -> GatingResult:
    """Offline Stage-II evaluation of one (C, B, policy) candidate against a
    Stage-I occupancy trace (same execution schedule, per the paper)."""
    ch = char or characterize(capacity, banks)
    d = np.asarray(durations, np.float64)
    total_time = float(d.sum())

    e_dyn = n_reads * ch.e_read_j + n_writes * ch.e_write_j

    if not policy.gate:
        e_leak = ch.leak_w_per_bank * banks * total_time
        return GatingResult(policy.name, policy.alpha, capacity, banks,
                            e_dyn, e_leak, 0.0, 0, 0.0, banks * total_time,
                            ch.area_mm2)

    act = bank_activity(occupancy, policy.alpha, capacity, banks)
    on = bank_on_matrix(act, banks)                     # (nseg, B)
    threshold = policy.min_gate_multiple * ch.break_even_s

    # a bank is ON while required AND during idle intervals too short to gate
    drowsy = (policy.drowsy_fraction != 1.0
              or policy.drowsy_switch_fraction != 0.0)
    gated_seconds = 0.0
    drowsy_seconds = 0.0
    n_sw = 0
    n_drowsy = 0
    on_final = np.ones_like(on)
    for b in range(banks):
        run_d, starts, ends = idle_runs(d, on[:, b])
        ok = run_d >= threshold
        n_sw += int(ok.sum())
        gated_seconds += float(run_d[ok].sum())
        for s, e in zip(starts[ok], ends[ok]):
            on_final[s:e, b] = False
        if drowsy:
            n_drowsy += int((~ok).sum())
            drowsy_seconds += float(run_d[~ok].sum())

    on_seconds = float((on_final * d[:, None]).sum())
    e_leak = ch.leak_w_per_bank * on_seconds
    e_sw = n_sw * ch.e_switch_j
    if drowsy:
        # short idle runs drop to retention voltage instead of staying fully
        # ON: swap their full-leak share for the retention fraction and pay
        # the (cheap) drowsy transition per run
        e_leak += ((policy.drowsy_fraction - 1.0) * ch.leak_w_per_bank
                   * drowsy_seconds)
        e_sw += n_drowsy * ch.e_switch_j * policy.drowsy_switch_fraction
    return GatingResult(policy.name, policy.alpha, capacity, banks,
                        e_dyn, e_leak, e_sw, n_sw, gated_seconds,
                        banks * total_time, ch.area_mm2,
                        drowsy_bank_seconds=drowsy_seconds,
                        n_drowsy=n_drowsy)


def bank_timeline(durations: np.ndarray, occupancy: np.ndarray, *,
                  capacity: int, banks: int, alpha: float) -> Dict[str, np.ndarray]:
    """Fig.-8 style artifact: per-segment activity + packing overhead."""
    act = bank_activity(occupancy, alpha, capacity, banks)
    usable = alpha * capacity / banks
    overhead = act * (capacity / banks) - np.minimum(
        act * usable, np.asarray(occupancy, np.float64))
    return {
        "durations": np.asarray(durations, np.float64),
        "occupancy": np.asarray(occupancy, np.float64),
        "active_banks": act,
        "placement_overhead_bytes": np.maximum(overhead, 0.0),
    }
