"""Power-gating policies + Eq. (2)-(5) energy model (TRAPTI Stage II).

    E_tot = E_dyn + E_leak + E_sw                                  (2)
    E_dyn = N_R * E_R + N_W * E_W                                  (3)
    E_leak ~= sum_k P_leak_bank * B_on(k) * dt_k                   (4)
    E_sw  = N_sw * E_sw_bank                                       (5)

Policies:
  * "none"         — no gating; all B banks leak for the whole run.
  * "aggressive"   — alpha = 1.0 packing; gate every idle-eligible interval
                     that passes the break-even criterion.
  * "conservative" — alpha = 0.9 headroom; additionally skip idle intervals
                     shorter than `min_gate_multiple` x break-even (avoids
                     thrashing and wake-up latency exposure).

`evaluate` is the *scalar reference*: one candidate at a time, per-bank
Python loops. Sweeps, campaigns and CLIs run on the batched engine
(`core.candidates.evaluate_candidates`), which is property-tested against
this function and evaluates the whole (C, B, alpha, policy) grid in one
vectorized call.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.core.banking import bank_activity, bank_on_matrix, idle_runs
from repro.core.cacti import SramCharacterization, characterize


@dataclass(frozen=True)
class Policy:
    name: str
    alpha: float
    gate: bool
    min_gate_multiple: float = 1.0      # x break-even time

    @staticmethod
    def none(alpha: float = 1.0) -> "Policy":
        return Policy("none", alpha, gate=False)

    @staticmethod
    def aggressive() -> "Policy":
        return Policy("aggressive", 1.0, gate=True, min_gate_multiple=1.0)

    @staticmethod
    def conservative(alpha: float = 0.9) -> "Policy":
        return Policy("conservative", alpha, gate=True, min_gate_multiple=5.0)


@dataclass
class GatingResult:
    policy: str
    alpha: float
    capacity: int
    banks: int
    e_dyn: float
    e_leak: float
    e_sw: float
    n_transitions: int
    gated_bank_seconds: float
    total_bank_seconds: float
    area_mm2: float

    @property
    def e_total(self) -> float:
        return self.e_dyn + self.e_leak + self.e_sw


def evaluate(durations: np.ndarray, occupancy: np.ndarray, *,
             capacity: int, banks: int, policy: Policy,
             n_reads: int, n_writes: int,
             char: Optional[SramCharacterization] = None) -> GatingResult:
    """Offline Stage-II evaluation of one (C, B, policy) candidate against a
    Stage-I occupancy trace (same execution schedule, per the paper)."""
    ch = char or characterize(capacity, banks)
    d = np.asarray(durations, np.float64)
    total_time = float(d.sum())

    e_dyn = n_reads * ch.e_read_j + n_writes * ch.e_write_j

    if not policy.gate:
        e_leak = ch.leak_w_per_bank * banks * total_time
        return GatingResult(policy.name, policy.alpha, capacity, banks,
                            e_dyn, e_leak, 0.0, 0, 0.0, banks * total_time,
                            ch.area_mm2)

    act = bank_activity(occupancy, policy.alpha, capacity, banks)
    on = bank_on_matrix(act, banks)                     # (nseg, B)
    threshold = policy.min_gate_multiple * ch.break_even_s

    # a bank is ON while required AND during idle intervals too short to gate
    gated_seconds = 0.0
    n_sw = 0
    on_final = np.ones_like(on)
    for b in range(banks):
        run_d, starts, ends = idle_runs(d, on[:, b])
        ok = run_d >= threshold
        n_sw += int(ok.sum())
        gated_seconds += float(run_d[ok].sum())
        for s, e in zip(starts[ok], ends[ok]):
            on_final[s:e, b] = False

    on_seconds = float((on_final * d[:, None]).sum())
    e_leak = ch.leak_w_per_bank * on_seconds
    e_sw = n_sw * ch.e_switch_j
    return GatingResult(policy.name, policy.alpha, capacity, banks,
                        e_dyn, e_leak, e_sw, n_sw, gated_seconds,
                        banks * total_time, ch.area_mm2)


def bank_timeline(durations: np.ndarray, occupancy: np.ndarray, *,
                  capacity: int, banks: int, alpha: float) -> Dict[str, np.ndarray]:
    """Fig.-8 style artifact: per-segment activity + packing overhead."""
    act = bank_activity(occupancy, alpha, capacity, banks)
    usable = alpha * capacity / banks
    overhead = act * (capacity / banks) - np.minimum(
        act * usable, np.asarray(occupancy, np.float64))
    return {
        "durations": np.asarray(durations, np.float64),
        "occupancy": np.asarray(occupancy, np.float64),
        "active_banks": act,
        "placement_overhead_bytes": np.maximum(overhead, 0.0),
    }
