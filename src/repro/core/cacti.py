"""CACTI-7-like analytical SRAM surrogate (45 nm, itrs-hp).

CACTI itself is a C++ binary we cannot run here; this surrogate is fit to the
paper's own Table II (which was produced with CACTI 7 at 45 nm itrs-hp), so
Stage II reproduces the paper's absolute scale:

  * leakage  — Table II B=1 rows are linear in C at fixed runtime:
               P_leak ≈ 0.682 W/MiB of cell array (+ periphery area leakage).
  * area     — linear cell area ≈ 16.78 mm²/MiB + 49.1 mm² + per-bank
               periphery ≈ 5.4·sqrt(bank_MiB) mm² (fit residual < 2.5%).
  * access   — wordline/bitline energy ~ sqrt(bank size) + H-tree routing
               ~ log2(B) (CACTI scaling shape, constants in the CACTI range).
  * gating   — sleep-transistor transition energy ~ 0.4 nJ/KiB of bank, giving
               break-even times well under 1 ms (the paper finds switching
               overhead negligible; we verify the same).
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass

MIB = float(2**20)

# --- calibrated constants (see DESIGN.md §8) --------------------------------
LEAK_W_PER_MIB = 0.682          # cell-array leakage
AREA_MM2_PER_MIB = 16.78
AREA_MM2_FIXED = 49.1
AREA_BANK_MM2_PER_SQRT_MIB = 5.4
LEAK_W_PER_MM2 = LEAK_W_PER_MIB / AREA_MM2_PER_MIB   # periphery leakage

E_ACC_BASE_NJ = 1.2             # per 64B access
E_ACC_SQRT_NJ = 0.35            # x sqrt(bank MiB)
E_ACC_ROUTE_NJ = 0.15           # x log2(B)

E_SW_NJ_PER_KIB = 0.4           # power-gate transition (off+on pair)
WAKEUP_LATENCY_NS = 1000.0


@dataclass(frozen=True)
class SramCharacterization:
    capacity: int                # bytes, total
    banks: int
    access_bytes: int = 64
    e_switch_scale: float = 1.0  # sensitivity hook: scales E_sw and break-even

    # ------------------------------------------------------------- derived
    @property
    def bank_bytes(self) -> int:
        return self.capacity // self.banks

    @property
    def bank_mib(self) -> float:
        return self.bank_bytes / MIB

    @property
    def cap_mib(self) -> float:
        return self.capacity / MIB

    # area ------------------------------------------------------------------
    @property
    def area_mm2(self) -> float:
        cell = AREA_MM2_PER_MIB * self.cap_mib + AREA_MM2_FIXED
        periphery = self.banks * AREA_BANK_MM2_PER_SQRT_MIB * math.sqrt(
            max(self.bank_mib, 1e-9))
        return cell + periphery

    # leakage ----------------------------------------------------------------
    @property
    def leak_w_total(self) -> float:
        """All banks on."""
        return self.banks * self.leak_w_per_bank

    @property
    def leak_w_per_bank(self) -> float:
        cell = LEAK_W_PER_MIB * self.bank_mib
        periphery = (AREA_BANK_MM2_PER_SQRT_MIB
                     * math.sqrt(max(self.bank_mib, 1e-9))) * LEAK_W_PER_MM2
        return cell + periphery

    # dynamic ----------------------------------------------------------------
    @property
    def e_read_j(self) -> float:
        nj = (E_ACC_BASE_NJ + E_ACC_SQRT_NJ * math.sqrt(max(self.bank_mib, 1e-9))
              + E_ACC_ROUTE_NJ * math.log2(max(self.banks, 1)))
        return nj * 1e-9

    @property
    def e_write_j(self) -> float:
        return 1.1 * self.e_read_j          # writes slightly costlier (CACTI)

    # power gating -------------------------------------------------------------
    @property
    def e_switch_j(self) -> float:
        """Energy of one off->on transition pair for one bank."""
        return (E_SW_NJ_PER_KIB * (self.bank_bytes / 1024) * 1e-9
                * self.e_switch_scale)

    @property
    def break_even_s(self) -> float:
        """Idle duration above which gating one bank saves net energy."""
        return self.e_switch_j / max(self.leak_w_per_bank, 1e-12)

    @property
    def access_latency_ns(self) -> float:
        from repro.sim.accelerator import sram_latency_ns
        return sram_latency_ns(self.bank_bytes) + 0.3 * math.log2(
            max(self.banks, 1))


@functools.lru_cache(maxsize=None)
def characterize(capacity_bytes: int, banks: int,
                 e_switch_scale: float = 1.0) -> SramCharacterization:
    """Memoized: sweeps/campaigns re-characterize identical (C, B) cells
    thousands of times; the instance is frozen, so sharing it is safe.

    `e_switch_scale` scales the per-transition energy *and* the implied
    break-even time — the sensitivity-study hook (replaces ad-hoc
    subclassing of `SramCharacterization`)."""
    return SramCharacterization(int(capacity_bytes), int(banks),
                                e_switch_scale=float(e_switch_scale))
