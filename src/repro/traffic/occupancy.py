"""Slot-level, time-resolved multi-tenant KV occupancy model.

Composes per-request prefill/decode phases into one on-chip occupancy step
function, without running the JAX model: the KV geometry comes from the
architecture config (MHA vs GQA vs sliding-window vs SSM state, via
`serve.scheduler.kv_bytes_at`), the schedule from a continuous-batching
discrete-event loop (FCFS admission into `num_slots` slots, lockstep decode),
and the timing from a first-order throughput model. The output is a
`TraceBundle` whose `OccupancyTrace` is byte-exact in its bookkeeping
(admitted == retired at drain), so `core.explorer.sweep` and
`core.gating.evaluate` run on it unchanged — serving traffic becomes a
first-class Stage-I workload.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.obs.telemetry import LATENCY_BUCKETS, Histogram
from repro.serve.scheduler import kv_bytes_at, slot_state_bytes
from repro.sim.trace import AccessStats, OccupancyTrace, TraceBundle
from repro.traffic.generators import RequestSpec, materialize_tokens


@dataclass(frozen=True)
class TimingModel:
    """First-order serving latencies: a prefill costs `prefill_tok_s` per
    prompt token (compute-bound), one lockstep decode iteration costs
    `decode_base_s` plus `decode_slot_s` per active slot (memory-bound)."""
    prefill_tok_s: float = 1.5e-4
    decode_base_s: float = 2e-3
    decode_slot_s: float = 5e-4

    @staticmethod
    def from_arch(cfg, *, peak_macs_per_s: float = 65.5e12,
                  prefill_util: float = 0.35,
                  decode_util: float = 0.02) -> "TimingModel":
        """Scale latencies with the model's per-token work on the paper's
        baseline accelerator (65.5 TMAC/s peak): prefill runs near peak,
        decode is KV-bandwidth-bound so its effective utilization is tiny."""
        macs_per_tok = cfg.active_param_count()
        return TimingModel(
            prefill_tok_s=macs_per_tok / (peak_macs_per_s * prefill_util),
            decode_base_s=5e-4,
            decode_slot_s=macs_per_tok / (peak_macs_per_s * decode_util))


@dataclass
class TrafficStats:
    admitted: int = 0
    finished: int = 0
    rejected: int = 0                  # queue overflow (never with inf queue)
    decode_steps: int = 0
    admitted_bytes: int = 0
    retired_bytes: int = 0
    peak_active_slots: int = 0
    queue_delay_s: List[float] = field(default_factory=list)
    latency_s: List[float] = field(default_factory=list)
    # inter-token gap distribution (standalone mergeable histogram; the
    # fast-forward path bulk-observes so it stays bit-identical to exact)
    tbt: Histogram = field(default_factory=lambda: Histogram(
        "traffic.tbt_s", edges=LATENCY_BUCKETS))

    @property
    def ttft_s(self) -> List[float]:
        """TTFT per request: queue delay is stamped *after* the prefill
        advance, so it already spans arrival -> first token."""
        return self.queue_delay_s

    def percentile_latency(self, q: float) -> float:
        return float(np.percentile(self.latency_s, q)) if self.latency_s else 0.0


@dataclass
class TrafficSim:
    """Result of one traffic run against one architecture."""
    arch_name: str
    bundle: TraceBundle
    stats: TrafficStats
    num_slots: int

    @property
    def trace(self) -> OccupancyTrace:
        return self.bundle.traces["kv"]

    @property
    def total_time(self) -> float:
        return self.bundle.total_time


def simulate_traffic(cfg, requests: Sequence[RequestSpec], *,
                     num_slots: int = 8, max_len: int = 2048,
                     kv_dtype_bytes: int = 2,
                     timing: Optional[TimingModel] = None,
                     mem_name: str = "kv",
                     fidelity: str = "auto", meter=None) -> TrafficSim:
    """Discrete-event continuous batching over `num_slots` KV slots.

    Each admitted request prefills its prompt (occupancy step of the full
    prompt KV + any fixed recurrent state), then gains one token of KV per
    lockstep decode iteration until `output_len` tokens are produced, then
    retires (occupancy drops by everything it held). Admission is FCFS and
    happens between decode iterations, exactly like `ContinuousBatcher`.

    `fidelity`: "exact" steps every lockstep decode iteration individually;
    "pss"/"auto" enable the periodic-steady-state fast forward — stretches
    of iterations with no admission, retirement or KV-growth kink are
    emitted in one vectorized batch. The fast path is *bit-identical* to
    the exact loop (same event list, same float time accumulation via
    cumsum, same stats), so "auto" always takes it; the knob exists to keep
    the two paths regression-testable against each other."""
    if fidelity not in ("exact", "pss", "auto"):
        raise ValueError(f"fidelity must be exact|pss|auto, got {fidelity}")
    timing = timing or TimingModel.from_arch(cfg)
    reqs = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
    pending = list(reversed(reqs))               # pop() = earliest arrival
    state_b = slot_state_bytes(cfg)

    cap = num_slots * (kv_bytes_at(cfg, max_len, kv_dtype_bytes) + state_b)
    trace = OccupancyTrace(mem_name, cap)
    access = AccessStats()
    stats = TrafficStats()

    @dataclass
    class _Slot:
        req: RequestSpec
        ctx: int                      # current context length
        produced: int                 # decoded tokens so far
        bytes: int
        t_admit: float
        tok_t: float                  # time of the last emitted token

    slots: List[Optional[_Slot]] = [None] * num_slots
    t = 0.0

    def admit() -> None:
        nonlocal t
        for i in range(num_slots):
            if slots[i] is not None or not pending:
                continue
            if pending[-1].arrival_s > t:
                break                         # FCFS: don't skip ahead in time
            r = pending.pop()
            ctx = min(r.prompt_len, max_len)
            t += ctx * timing.prefill_tok_s   # prefills serialize on the pool
            b = kv_bytes_at(cfg, ctx, kv_dtype_bytes) + state_b
            trace.event(t, b, 0)
            if meter is not None:
                meter.record(t, b, 0, rid=r.rid, tenant=r.prefix_id,
                             cause="admission")
            access.add_write(mem_name, b)
            slots[i] = _Slot(r, ctx, 0, b, r.arrival_s, t)
            stats.admitted += 1
            stats.admitted_bytes += b
            stats.queue_delay_s.append(t - r.arrival_s)
            stats.peak_active_slots = max(
                stats.peak_active_slots, sum(s is not None for s in slots))
            if r.output_len <= 1:
                retire(i)       # prefill's first token already satisfied it

    def retire(i: int) -> None:
        s = slots[i]
        trace.event(t, -s.bytes, 0)
        if meter is not None:
            meter.record(t, -s.bytes, 0, rid=s.req.rid,
                         tenant=s.req.prefix_id)
        stats.retired_bytes += s.bytes
        stats.finished += 1
        stats.latency_s.append(t - s.req.arrival_s)
        slots[i] = None

    def kv_growth(ctx: int) -> int:
        if ctx >= max_len:
            return 0
        return (kv_bytes_at(cfg, ctx + 1, kv_dtype_bytes)
                - kv_bytes_at(cfg, ctx, kv_dtype_bytes))

    def ff_window(active: List[int]) -> int:
        """Lockstep iterations that are provably uneventful: no retirement,
        no KV-growth kink (saturation), no admission opportunity. Within
        the window every slot's growth is constant, so the iterations can
        be emitted in one vectorized batch, bit-identically."""
        k = min(slots[i].req.output_len - 1 - slots[i].produced
                for i in active) - 1          # stop before any retirement
        for i in active:
            s = slots[i]
            if s.ctx >= max_len:
                continue
            b0 = kv_bytes_at(cfg, s.ctx, kv_dtype_bytes)
            d1 = kv_bytes_at(cfg, s.ctx + 1, kv_dtype_bytes) - b0
            w = max_len - s.ctx
            # shrink to an affine stretch (handles local-window kinks)
            while w > 1 and (kv_bytes_at(cfg, s.ctx + w, kv_dtype_bytes)
                             - b0) != w * d1:
                w //= 2
            k = min(k, w)
        return k

    def fast_forward(active: List[int], k: int, dt: float) -> None:
        nonlocal t
        # sequential float accumulation: cumsum([t, dt, ...]) reproduces the
        # exact loop's `t += dt` chain bit-for-bit
        ts = np.cumsum(np.r_[t, np.full(k, dt)])[1:]
        if pending and any(s is None for s in slots):
            a = pending[-1].arrival_s
            stop = int(np.searchsorted(ts, a, side="left"))
            if stop < k:
                k, ts = stop + 1, ts[:stop + 1]   # admit on the next pass
        stats.decode_steps += k
        grow: List[int] = []
        grow_meta: List[RequestSpec] = []
        for i in active:
            s = slots[i]
            d1 = kv_growth(s.ctx)
            access.add_read(mem_name,
                            k * s.bytes + d1 * (k * (k - 1) // 2))
            if d1:
                grow.append(d1)
                grow_meta.append(s.req)
                s.bytes += k * d1
                access.add_write(mem_name, k * d1)
                stats.admitted_bytes += k * d1
            s.ctx = min(s.ctx + k, max_len)
            s.produced += k
            # diff over [last token, window tokens] yields the same float
            # subtractions the exact loop performs step by step
            stats.tbt.observe_array(np.diff(np.r_[s.tok_t, ts]))
            s.tok_t = float(ts[-1])
        if grow:
            trace.extend(np.repeat(ts, len(grow)),
                         np.tile(np.asarray(grow, np.int64), k),
                         np.zeros(k * len(grow), np.int64))
            if meter is not None:
                # element-for-element mirror of the bulk emission above
                # (ts-major, slots inner), so the meter's trace stays a
                # verbatim copy of the sim's
                meter.record_bulk(
                    np.repeat(ts, len(grow)),
                    np.tile(np.asarray(grow, np.int64), k),
                    np.zeros(k * len(grow), np.int64),
                    rids=[r.rid for r in grow_meta] * k,
                    tenants=[r.prefix_id for r in grow_meta] * k,
                    cause="decode_growth")
        t = float(ts[-1])

    while pending or any(s is not None for s in slots):
        admit()
        active = [i for i in range(num_slots) if slots[i] is not None]
        if not active:
            if not pending:
                break        # everything retired at admission (1-token reqs)
            # pool drained: jump to the next arrival (occupancy is zero in
            # the gap — the fluctuation power gating feeds on)
            t = max(t, pending[-1].arrival_s)
            continue
        if fidelity != "exact":
            k = ff_window(active)
            if k > 1:
                fast_forward(active, k,
                             timing.decode_base_s
                             + timing.decode_slot_s * len(active))
                continue
        t += timing.decode_base_s + timing.decode_slot_s * len(active)
        stats.decode_steps += 1
        for i in active:
            s = slots[i]
            stats.tbt.observe(t - s.tok_t)
            s.tok_t = t
            # attention reads all resident KV, then appends one row (the
            # bounded cache stops growing at max_len, like ContinuousBatcher)
            access.add_read(mem_name, s.bytes)
            nxt_ctx = min(s.ctx + 1, max_len)
            d = (kv_bytes_at(cfg, nxt_ctx, kv_dtype_bytes)
                 - kv_bytes_at(cfg, s.ctx, kv_dtype_bytes))
            s.ctx = nxt_ctx
            s.produced += 1
            if d:
                s.bytes += d
                trace.event(t, d, 0)
                if meter is not None:
                    meter.record(t, d, 0, rid=s.req.rid,
                                 tenant=s.req.prefix_id,
                                 cause="decode_growth")
                access.add_write(mem_name, d)
                stats.admitted_bytes += d
            # the prefill's argmax already yielded token #1, so `output_len`
            # generations need output_len - 1 decode iterations
            if s.produced >= s.req.output_len - 1:
                retire(i)

    bundle = TraceBundle(graph_name=f"{cfg.name}-traffic",
                         total_time=max(t, 1e-9),
                         traces={mem_name: trace}, access=access)
    return TrafficSim(cfg.name, bundle, stats, num_slots)


# ---------------------------------------------------------------------------
# Shared-prefix occupancy analysis (page-granular, model-free)
# ---------------------------------------------------------------------------

@dataclass
class PrefixTrafficStats(TrafficStats):
    prefix_hits: int = 0
    prefix_tokens_reused: int = 0
    cow_splits: int = 0
    evicted_pages: int = 0


def simulate_prefix_traffic(cfg, requests: Sequence[RequestSpec], *,
                            num_slots: int = 8, page_size: int = 16,
                            num_pages: Optional[int] = None,
                            max_len: int = 2048, kv_dtype_bytes: int = 2,
                            timing: Optional[TimingModel] = None,
                            vocab_size: int = 50000,
                            seed: int = 0, meter=None) -> TrafficSim:
    """Page-granular continuous batching with prefix sharing, model-free.

    The same host machinery the real batcher runs — `RadixPrefixIndex`
    probe/insert, refcounted COW page allocation, LRU leaf eviction —
    driven by materialized token streams instead of a JAX model, with the
    KV geometry from `serve.paged.page_bytes` and the timing from the
    first-order `TimingModel` (prefix hits skip the matched run's prefill
    time). The result is a `TraceBundle` carrying the **dual traces**:
    "kv" is physical occupancy (unique slot-referenced pages as needed,
    cache-resident pages as obsolete) and "kv_logical" the per-slot demand
    sum — so `core.explorer.sweep` / `traffic.campaign` price banking and
    gating against true residency unchanged, and logical-vs-physical is
    the sharing headroom. Full-attention KV only (recurrent state is
    context-independent and contributes no sharable bytes)."""
    from repro.serve.paged import page_bytes as paged_page_bytes, pages_for
    from repro.serve.prefix import SharedKVLedger

    timing = timing or TimingModel.from_arch(cfg)
    ps = page_size
    slot_cap_pages = pages_for(max_len, ps)
    if num_pages is None:
        # live worst case + an equal-size allowance for the reuse cache
        num_pages = 1 + 2 * num_slots * slot_cap_pages
    pb = paged_page_bytes(cfg, ps, kv_dtype_bytes)
    ledger = SharedKVLedger(num_pages, pb, ps, num_slots=num_slots,
                            max_pages_per_slot=slot_cap_pages)
    ledger.meter = meter
    access = AccessStats()
    stats = PrefixTrafficStats()
    mem_name = "kv"

    def worst_pages(r: RequestSpec) -> int:
        S = min(r.prompt_len, max_len)
        w = pages_for(min(S + max(r.output_len - 1, 0), max_len), ps)
        return w + (1 if S % ps and r.output_len > 1 else 0)

    # reject requests no drained pool could ever hold (the batcher raises
    # OutOfPages at submit for the same condition) — admitting them would
    # stall the FCFS queue forever
    reqs, rejected = [], 0
    for r in sorted(requests, key=lambda r: (r.arrival_s, r.rid)):
        if (worst_pages(r) > num_pages - 1
                or pages_for(min(r.prompt_len, max_len), ps)
                > slot_cap_pages):
            rejected += 1
        else:
            reqs.append(r)
    stats.rejected = rejected
    tokens = materialize_tokens(reqs, vocab_size, seed)
    pending = list(reversed(list(zip(reqs, tokens))))

    @dataclass
    class _Slot:
        req: RequestSpec
        ctx: int
        produced: int
        tok_t: float

    slots: List[Optional[_Slot]] = [None] * num_slots
    reserved = [0] * num_slots
    t = 0.0

    def available() -> int:
        return ledger.allocator.n_free - sum(reserved)

    def admit() -> None:
        nonlocal t
        for i in range(num_slots):
            if slots[i] is not None or not pending:
                continue
            r, toks = pending[-1]
            if r.arrival_s > t:
                break                    # FCFS: don't skip ahead in time
            S = min(r.prompt_len, max_len)
            toks = toks[:S]
            worst_total = pages_for(
                min(S + max(r.output_len - 1, 0), max_len), ps)
            cow_extra = 1 if (S % ps and r.output_len > 1) else 0
            match = ledger.index.probe(toks, limit=S - 1)
            short = worst_total - len(match.pages) + cow_extra - available()
            while short > 0:
                freed = ledger.evict_for(short, t)
                if not freed:
                    break
                stats.evicted_pages += freed
                match = ledger.index.probe(toks, limit=S - 1)
                short = (worst_total - len(match.pages) + cow_extra
                         - available())
            if short > 0:
                break                    # FCFS: wait for pages
            pending.pop()
            m = match.tokens(ps)
            fresh_n = pages_for(S, ps) - len(match.pages)
            t += (S - m) * timing.prefill_tok_s       # prefill skip
            if meter is not None:
                ledger.set_slot_meta(i, r.rid, r.prefix_id)
            ledger.admit(i, fresh_n, t, shared=match.pages)
            ledger.insert_run(toks, ledger.slot_pages[i], t)
            reserved[i] = worst_total - len(match.pages) + cow_extra - fresh_n
            slots[i] = _Slot(r, S, 0, t)
            access.add_write(mem_name, (S - m) * (pb // ps))
            stats.admitted += 1
            stats.admitted_bytes += fresh_n * pb
            if m:
                stats.prefix_hits += 1
                stats.prefix_tokens_reused += m
            stats.queue_delay_s.append(t - r.arrival_s)
            stats.peak_active_slots = max(
                stats.peak_active_slots, sum(s is not None for s in slots))
            if r.output_len <= 1:
                retire(i)

    def retire(i: int) -> None:
        s = slots[i]
        freed = ledger.retire(i, t)
        stats.retired_bytes += freed * pb
        stats.finished += 1
        stats.latency_s.append(t - s.req.arrival_s)
        reserved[i] = 0
        slots[i] = None

    while pending or any(s is not None for s in slots):
        admit()
        active = [i for i in range(num_slots) if slots[i] is not None]
        if not active:
            if not pending:
                break
            nxt = max(t, pending[-1][0].arrival_s)
            if nxt == t:
                # the head arrived, every slot is free, and admit() still
                # failed: the feasibility filter should make this
                # unreachable — fail loudly rather than spin forever
                raise RuntimeError(
                    "prefix traffic sim stalled: queue head cannot admit "
                    "into a drained pool")
            t = nxt
            continue
        t += timing.decode_base_s + timing.decode_slot_s * len(active)
        stats.decode_steps += 1
        for i in active:
            s = slots[i]
            stats.tbt.observe(t - s.tok_t)
            s.tok_t = t
            access.add_read(mem_name, pages_for(s.ctx, ps) * pb)
            if s.ctx < max_len:
                idx = s.ctx // ps
                pages = ledger.slot_pages[i]
                if idx < len(pages):
                    if ledger.allocator.refcount(pages[idx]) > 1:
                        ledger.cow(i, idx, t)     # divergent write: COW split
                        reserved[i] -= 1
                        stats.cow_splits += 1
                else:
                    ledger.grow(i, idx + 1, t)
                    reserved[i] -= 1
                access.add_write(mem_name, pb // ps)
                s.ctx += 1
            s.produced += 1
            if s.produced >= s.req.output_len - 1:
                retire(i)

    bundle = TraceBundle(graph_name=f"{cfg.name}-prefix-traffic",
                         total_time=max(t, 1e-9),
                         traces={"kv": ledger.trace,
                                 "kv_logical": ledger.logical},
                         access=access)
    return TrafficSim(cfg.name, bundle, stats, num_slots)


# ---------------------------------------------------------------------------
# Speculative-decoding occupancy analysis (page-granular, model-free)
# ---------------------------------------------------------------------------

@dataclass
class SpecTrafficStats(TrafficStats):
    spec_rounds: int = 0
    drafted_tokens: int = 0
    accepted_tokens: int = 0
    rolled_back_pages: int = 0

    @property
    def acceptance_rate(self) -> float:
        """Accepted draft tokens / drafted tokens (the bonus token each
        round contributes is excluded, matching the usual definition)."""
        if not self.drafted_tokens:
            return 0.0
        return (self.accepted_tokens - self.spec_rounds) / self.drafted_tokens


def simulate_spec_traffic(cfg, requests: Sequence[RequestSpec], *,
                          num_slots: int = 8, page_size: int = 16,
                          num_pages: Optional[int] = None,
                          max_len: int = 2048, spec_k: int = 4,
                          acceptance: float = 0.7,
                          draft_kv_frac: float = 0.5,
                          kv_dtype_bytes: int = 2,
                          timing: Optional[TimingModel] = None,
                          seed: int = 0, meter=None) -> TrafficSim:
    """Page-granular continuous batching under speculative decoding.

    Mirrors the real `PagedContinuousBatcher(speculate_k=...)` loop through
    the same `PagedKVLedger` (both page lanes, draft pages priced at
    `draft_kv_frac` of a target page) without running a model: each active
    slot per lockstep round bursts its lanes to the k+1-row verify window,
    accepts ``m = 1 + <leading Bernoulli(acceptance) run over k drafts>``
    tokens, then rolls the rejected suffix back via
    `PagedKVLedger.truncate_rows` — so the trace carries the speculative
    occupancy signature (per-round sawtooth of burst/rollback deltas) that
    the serving path produces, and feeds Stage-II (`core.explorer.sweep`,
    `traffic.campaign`) unchanged. Round latency scales the lockstep decode
    iteration by ``1 + (k+1) * draft_kv_frac``: one verify pass plus k+1
    draft steps at the draft's relative cost (for self-speculation the KV
    fraction and the compute fraction are both the kept-layer fraction)."""
    from repro.serve.paged import PagedKVLedger, pages_for
    from repro.serve.paged import page_bytes as paged_page_bytes

    if spec_k < 1:
        raise ValueError(f"spec_k must be >= 1, got {spec_k}")
    if not 0.0 <= acceptance <= 1.0:
        raise ValueError(f"acceptance must be in [0, 1], got {acceptance}")
    timing = timing or TimingModel.from_arch(cfg)
    ps = page_size
    V = spec_k + 1
    pb = paged_page_bytes(cfg, ps, kv_dtype_bytes)
    draft_pb = max(1, int(round(pb * draft_kv_frac)))
    draft_time = 1.0 + V * draft_kv_frac      # round time vs one decode step
    if num_pages is None:
        num_pages = 1 + 2 * num_slots * pages_for(max_len, ps)
    ledger = PagedKVLedger(num_pages, pb, ps)
    ledger.enable_draft_lane(draft_pb)
    ledger.meter = meter
    access = AccessStats()
    stats = SpecTrafficStats()
    rng = np.random.default_rng(seed)
    mem_name = "kv"

    def worst_pages(r: RequestSpec) -> int:
        """Per-lane worst case: the verify window overshoots the final
        context by up to k rows before the last rollback truncates it."""
        S = min(r.prompt_len, max_len)
        extra = spec_k if r.output_len > 1 else 0
        return pages_for(min(S + max(r.output_len - 1, 0) + extra, max_len),
                         ps)

    reqs, rejected = [], 0
    for r in sorted(requests, key=lambda r: (r.arrival_s, r.rid)):
        if 2 * worst_pages(r) > num_pages - 1:
            rejected += 1
        else:
            reqs.append(r)
    stats.rejected = rejected
    pending = list(reversed(reqs))

    @dataclass
    class _Slot:
        req: RequestSpec
        ctx: int
        produced: int
        tok_t: float

    slots: List[Optional[_Slot]] = [None] * num_slots
    reserved = [0] * num_slots
    t = 0.0

    def available() -> int:
        return ledger.allocator.n_free - sum(reserved)

    def admit() -> None:
        nonlocal t
        for i in range(num_slots):
            if slots[i] is not None or not pending:
                continue
            r = pending[-1]
            if r.arrival_s > t:
                break                    # FCFS: don't skip ahead in time
            if 2 * worst_pages(r) > available():
                break                    # FCFS: wait for pages
            pending.pop()
            S = min(r.prompt_len, max_len)
            npg = pages_for(S, ps)
            # both lanes prefill the full prompt (the draft lane never
            # shares, so speculation costs a second, cheaper prefill)
            t += S * timing.prefill_tok_s * (1.0 + draft_kv_frac)
            if meter is not None:
                ledger.set_slot_meta(i, r.rid, r.prefix_id)
            ledger.admit(i, npg, t)
            ledger.admit_draft(i, npg, t)
            reserved[i] = 2 * (worst_pages(r) - npg)
            slots[i] = _Slot(r, S, 0, t)
            access.add_write(mem_name, S * (pb // ps) + S * (draft_pb // ps))
            stats.admitted += 1
            stats.admitted_bytes += npg * (pb + draft_pb)
            stats.queue_delay_s.append(t - r.arrival_s)
            stats.peak_active_slots = max(
                stats.peak_active_slots, sum(s is not None for s in slots))
            if r.output_len <= 1:
                retire(i)

    def retire(i: int) -> None:
        s = slots[i]
        held = (len(ledger.slot_pages[i]) * pb
                + len(ledger.draft_pages.get(i, [])) * draft_pb)
        ledger.retire(i, t)
        stats.retired_bytes += held
        stats.finished += 1
        stats.latency_s.append(t - s.req.arrival_s)
        reserved[i] = 0
        slots[i] = None

    while pending or any(s is not None for s in slots):
        admit()
        active = [i for i in range(num_slots) if slots[i] is not None]
        if not active:
            if not pending:
                break
            nxt = max(t, pending[-1].arrival_s)
            if nxt == t:
                raise RuntimeError(
                    "spec traffic sim stalled: queue head cannot admit "
                    "into a drained pool")
            t = nxt
            continue
        # one speculative round per active slot: verify pass + k+1 draft
        # steps, all inside one lockstep iteration's wall-clock envelope
        t += (timing.decode_base_s
              + timing.decode_slot_s * len(active)) * draft_time
        stats.decode_steps += 1
        for i in active:
            s = slots[i]
            rem = s.req.output_len - 1 - s.produced
            # burst: both lanes grow to the verify window's worst case
            burst_rows = min(s.ctx + V, max_len)
            npg = pages_for(burst_rows, ps)
            fresh = npg - len(ledger.slot_pages[i])
            if fresh > 0:
                ledger.grow(i, npg, t)
                ledger.grow_draft(i, npg, t)
                reserved[i] -= 2 * fresh
                stats.admitted_bytes += fresh * (pb + draft_pb)
            # target reads the window's pages once (batched verify); the
            # draft re-reads them for each of its k+1 sequential steps
            access.add_read(mem_name, npg * pb + V * npg * draft_pb)
            access.add_write(mem_name, V * (pb // ps) + V * (draft_pb // ps))
            # m = 1 + leading Bernoulli(acceptance) run over the k drafts
            draws = rng.random(spec_k) < acceptance
            lead = spec_k if draws.all() else int(np.argmin(draws))
            m = min(1 + lead, rem)
            s.ctx = min(s.ctx + m, max_len)
            s.produced += m
            stats.spec_rounds += 1
            stats.drafted_tokens += spec_k
            stats.accepted_tokens += m
            stats.tbt.observe_array(np.diff(np.r_[s.tok_t,
                                                  np.full(m, t)]))
            s.tok_t = t
            # rollback: truncate the rejected suffix out of both lanes
            ft, fd = ledger.truncate_rows(i, s.ctx, t)
            freed = len(ft) + len(fd)
            if freed:
                reserved[i] += freed
                stats.rolled_back_pages += freed
            if s.produced >= s.req.output_len - 1:
                retire(i)

    bundle = TraceBundle(graph_name=f"{cfg.name}-spec-traffic",
                         total_time=max(t, 1e-9),
                         traces={mem_name: ledger.trace}, access=access)
    return TrafficSim(cfg.name, bundle, stats, num_slots)


def utilization_summary(sim: TrafficSim) -> Dict[str, float]:
    """Headline occupancy numbers + serving SLO percentiles for reports."""
    tr = sim.trace
    st = sim.stats
    ttft = st.queue_delay_s
    return {
        "peak_bytes": float(tr.peak_needed()),
        "mean_bytes": tr.time_weighted_mean(sim.total_time),
        "capacity_bytes": float(tr.capacity),
        "peak_frac_of_capacity": (tr.peak_needed() / tr.capacity
                                  if tr.capacity else 0.0),
        "finished": float(st.finished),
        "p50_latency_s": st.percentile_latency(50),
        "p95_latency_s": st.percentile_latency(95),
        "ttft_p50_s": float(np.percentile(ttft, 50)) if ttft else 0.0,
        "ttft_p99_s": float(np.percentile(ttft, 99)) if ttft else 0.0,
        "tbt_p50_s": st.tbt.quantile(0.5) if st.tbt.count else 0.0,
        "tbt_p99_s": st.tbt.quantile(0.99) if st.tbt.count else 0.0,
    }
