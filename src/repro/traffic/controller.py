"""Online bank power-gating controller simulated against a live trace.

Stage II's `core.gating.evaluate` is an *offline oracle*: it sees each idle
interval's full duration before deciding to gate, so it gates exactly the
runs that pass the break-even criterion. A deployable controller only knows
the past. The classic online policy (ski-rental / timeout) is implemented
here: a bank that has been idle for `hysteresis_multiple x break_even_s`
(per `core.cacti.characterize`) is gated off, and is woken — paying the
transition energy and exposing `WAKEUP_LATENCY_NS` to the consumer — the
moment demand returns. With hysteresis h = break-even this policy is
2-competitive; energy always satisfies

    oracle  <=  online        (the oracle skips exactly the leakage the
                               online controller burns while waiting out h)

and on traces whose gated idle runs exceed h + break_even the online result
also beats the no-gating baseline.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.banking import bank_activity, bank_on_matrix, idle_runs
from repro.core.cacti import WAKEUP_LATENCY_NS, SramCharacterization, \
    characterize
from repro.core.candidates import Candidate, evaluate_candidates
from repro.core.gating import GatingResult, Policy


@dataclass(frozen=True)
class ControllerConfig:
    alpha: float = 0.9                   # packing headroom (Eq. 1)
    hysteresis_multiple: float = 2.0     # x break-even before gating off
    wake_latency_s: float = WAKEUP_LATENCY_NS * 1e-9


@dataclass
class OnlineResult:
    """GatingResult + the online-only observables."""
    gating: GatingResult
    wake_violations: int                 # wakes on the critical path
    stall_s: float                       # total wake-up latency exposed
    hysteresis_s: float

    @property
    def e_total(self) -> float:
        return self.gating.e_total


def simulate_online(durations: np.ndarray, occupancy: np.ndarray, *,
                    capacity: int, banks: int,
                    cfg: Optional[ControllerConfig] = None,
                    n_reads: int = 0, n_writes: int = 0,
                    char: Optional[SramCharacterization] = None
                    ) -> OnlineResult:
    """Walk the trace causally with the timeout policy.

    Per idle run of length `r` with hysteresis `h`: the bank leaks for
    min(r, h); if r >= h it is gated for r - h (one off/on transition pair)
    and its wake at the end of the run is a latency violation unless the run
    closes the trace."""
    cfg = cfg or ControllerConfig()
    ch = char or characterize(capacity, banks)
    d = np.asarray(durations, np.float64)
    occ = np.asarray(occupancy)
    total_time = float(d.sum())
    h = cfg.hysteresis_multiple * ch.break_even_s

    e_dyn = n_reads * ch.e_read_j + n_writes * ch.e_write_j

    act = bank_activity(occ, cfg.alpha, capacity, banks)
    on = bank_on_matrix(act, banks)

    on_seconds = 0.0
    gated_seconds = 0.0
    n_sw = 0
    violations = 0
    for b in range(banks):
        busy = float(d[on[:, b]].sum())
        run_d, starts, ends = idle_runs(d, on[:, b])
        waited = np.minimum(run_d, h)            # leak while the timer runs
        gated = run_d - waited
        gates = gated > 0
        n_sw += int(gates.sum())
        gated_seconds += float(gated.sum())
        on_seconds += busy + float(waited.sum())
        # a gated run that ends inside the trace wakes on demand: latency hit
        violations += int((gates & (ends < len(d))).sum())

    stall = violations * cfg.wake_latency_s
    e_leak = ch.leak_w_per_bank * on_seconds
    e_sw = n_sw * ch.e_switch_j
    g = GatingResult(policy=f"online(h={cfg.hysteresis_multiple:g}xBE)",
                     alpha=cfg.alpha, capacity=capacity, banks=banks,
                     e_dyn=e_dyn, e_leak=e_leak, e_sw=e_sw,
                     n_transitions=n_sw, gated_bank_seconds=gated_seconds,
                     total_bank_seconds=banks * total_time,
                     area_mm2=ch.area_mm2)
    return OnlineResult(g, violations, stall, h)


@dataclass
class ControllerComparison:
    """online vs offline-oracle vs no-gating on the same trace/(C,B)."""
    online: OnlineResult
    oracle: GatingResult
    none: GatingResult

    @property
    def online_vs_none_pct(self) -> float:
        return 100.0 * (self.online.e_total / self.none.e_total - 1.0)

    @property
    def online_vs_oracle_pct(self) -> float:
        return 100.0 * (self.online.e_total / self.oracle.e_total - 1.0)

    def format(self) -> str:
        o, g, n = self.online, self.oracle, self.none
        return (f"E[mJ] none={n.e_total*1e3:.1f} "
                f"oracle={g.e_total*1e3:.1f} "
                f"online={o.e_total*1e3:.1f} "
                f"({self.online_vs_none_pct:+.1f}% vs none, "
                f"{self.online_vs_oracle_pct:+.1f}% vs oracle)  "
                f"wakes={o.wake_violations} stall={o.stall_s*1e6:.1f}us")


def _offline_candidates(capacity: int, banks: int, cfg: ControllerConfig,
                        oracle_policy: Optional[Policy]) -> List[Candidate]:
    """The two offline legs of one comparison as engine candidates."""
    pol = oracle_policy or Policy(
        "oracle", cfg.alpha, gate=True,
        min_gate_multiple=cfg.hysteresis_multiple)
    return [
        Candidate(capacity, banks, pol.alpha,
                  "gate" if pol.gate else "none", pol.min_gate_multiple,
                  label=pol.name),
        Candidate(capacity, banks, cfg.alpha, "none", label="none"),
    ]


def compare(durations: np.ndarray, occupancy: np.ndarray, *,
            capacity: int, banks: int, n_reads: int, n_writes: int,
            cfg: Optional[ControllerConfig] = None,
            oracle_policy: Optional[Policy] = None,
            backend: str = "auto") -> ControllerComparison:
    """The paper-style three-way comparison at one (C, B) point.

    The oracle uses `min_gate_multiple == hysteresis_multiple` so both
    policies gate the same set of idle runs — the gap between them is then
    purely the leakage burned during the online timer. The offline legs run
    on the batched engine; grid sweeps should prefer `compare_grid`, which
    batches them across every (C, B) point in one call."""
    cfg = cfg or ControllerConfig()
    ch = characterize(capacity, banks)
    online = simulate_online(durations, occupancy, capacity=capacity,
                             banks=banks, n_reads=n_reads, n_writes=n_writes,
                             cfg=cfg, char=ch)
    res = evaluate_candidates(
        durations, occupancy,
        _offline_candidates(capacity, banks, cfg, oracle_policy),
        n_reads=n_reads, n_writes=n_writes, backend=backend)
    return ControllerComparison(online, res.gating_result(0),
                                res.gating_result(1))


def compare_grid(durations: np.ndarray, occupancy: np.ndarray, *,
                 points: Sequence[Tuple[int, int]], n_reads: int,
                 n_writes: int, cfg: Optional[ControllerConfig] = None,
                 backend: str = "auto"
                 ) -> Dict[Tuple[int, int], ControllerComparison]:
    """Three-way comparisons for every (capacity, banks) point at once.

    Both offline legs of every point go through one batched
    `evaluate_candidates` call; the causal online controller (inherently
    sequential over the trace) still runs per point."""
    cfg = cfg or ControllerConfig()
    cands: List[Candidate] = []
    for cap, b in points:
        cands.extend(_offline_candidates(cap, b, cfg, None))
    res = evaluate_candidates(durations, occupancy, cands, n_reads=n_reads,
                              n_writes=n_writes, backend=backend)
    out: Dict[Tuple[int, int], ControllerComparison] = {}
    for i, (cap, b) in enumerate(points):
        online = simulate_online(durations, occupancy, capacity=cap, banks=b,
                                 n_reads=n_reads, n_writes=n_writes, cfg=cfg)
        out[(cap, b)] = ControllerComparison(
            online, res.gating_result(2 * i), res.gating_result(2 * i + 1))
    return out
