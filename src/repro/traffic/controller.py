"""Online bank power-gating controller simulated against a live trace.

Stage II's `core.gating.evaluate` is an *offline oracle*: it sees each idle
interval's full duration before deciding to gate, so it gates exactly the
runs that pass the break-even criterion. A deployable controller only knows
the past. The classic online policy (ski-rental / timeout) is implemented
here: a bank that has been idle for `hysteresis_multiple x break_even_s`
(per `core.cacti.characterize`) is gated off, and is woken — paying the
transition energy and exposing `WAKEUP_LATENCY_NS` to the consumer — the
moment demand returns. With hysteresis h = break-even this policy is
2-competitive; energy always satisfies

    oracle  <=  online        (the oracle skips exactly the leakage the
                               online controller burns while waiting out h)

and on traces whose gated idle runs exceed h + break_even the online result
also beats the no-gating baseline.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.banking import bank_activity, bank_on_matrix, idle_runs
from repro.core.cacti import WAKEUP_LATENCY_NS, SramCharacterization, \
    characterize
from repro.core.candidates import Candidate, evaluate_candidates
from repro.core.gating import GatingResult, Policy
from repro.sim.pss import AffineForecaster


@dataclass(frozen=True)
class ControllerConfig:
    alpha: float = 0.9                   # packing headroom (Eq. 1)
    hysteresis_multiple: float = 2.0     # x break-even before gating off
    wake_latency_s: float = WAKEUP_LATENCY_NS * 1e-9


@dataclass(frozen=True)
class ForecastConfig:
    """Knobs of the forecast leg (`simulate_online_forecast`).

    `window_s` trades noise immunity against reaction time of the fitted
    trend; `lead_s` is the pre-wake horizon — it bounds how early a bank
    may wake, so it also bounds the leakage one avoided violation costs
    (roughly ``lead_s x leak_w_per_bank``)."""
    window_s: float = 2.0                # trailing least-squares fit window
    lead_s: Optional[float] = None       # pre-wake horizon; None → window/20

    @property
    def lead(self) -> float:
        return self.lead_s if self.lead_s is not None else self.window_s / 20


@dataclass
class OnlineResult:
    """GatingResult + the online-only observables."""
    gating: GatingResult
    wake_violations: int                 # wakes on the critical path
    stall_s: float                       # total wake-up latency exposed
    hysteresis_s: float
    # forecast-leg observables (zero for the reactive controller)
    pre_wakes: int = 0                   # forecast-triggered early wakes
    early_wake_s: float = 0.0            # leakage seconds those wakes cost

    @property
    def e_total(self) -> float:
        return self.gating.e_total


def simulate_online(durations: np.ndarray, occupancy: np.ndarray, *,
                    capacity: int, banks: int,
                    cfg: Optional[ControllerConfig] = None,
                    n_reads: int = 0, n_writes: int = 0,
                    char: Optional[SramCharacterization] = None
                    ) -> OnlineResult:
    """Walk the trace causally with the timeout policy.

    Per idle run of length `r` with hysteresis `h`: the bank leaks for
    min(r, h); if r >= h it is gated for r - h (one off/on transition pair)
    and its wake at the end of the run is a latency violation unless the run
    closes the trace."""
    cfg = cfg or ControllerConfig()
    ch = char or characterize(capacity, banks)
    d = np.asarray(durations, np.float64)
    occ = np.asarray(occupancy)
    total_time = float(d.sum())
    h = cfg.hysteresis_multiple * ch.break_even_s

    e_dyn = n_reads * ch.e_read_j + n_writes * ch.e_write_j

    act = bank_activity(occ, cfg.alpha, capacity, banks)
    on = bank_on_matrix(act, banks)

    on_seconds = 0.0
    gated_seconds = 0.0
    n_sw = 0
    violations = 0
    for b in range(banks):
        busy = float(d[on[:, b]].sum())
        run_d, starts, ends = idle_runs(d, on[:, b])
        waited = np.minimum(run_d, h)            # leak while the timer runs
        gated = run_d - waited
        gates = gated > 0
        n_sw += int(gates.sum())
        gated_seconds += float(gated.sum())
        on_seconds += busy + float(waited.sum())
        # a gated run that ends inside the trace wakes on demand: latency hit
        violations += int((gates & (ends < len(d))).sum())

    stall = violations * cfg.wake_latency_s
    e_leak = ch.leak_w_per_bank * on_seconds
    e_sw = n_sw * ch.e_switch_j
    g = GatingResult(policy=f"online(h={cfg.hysteresis_multiple:g}xBE)",
                     alpha=cfg.alpha, capacity=capacity, banks=banks,
                     e_dyn=e_dyn, e_leak=e_leak, e_sw=e_sw,
                     n_transitions=n_sw, gated_bank_seconds=gated_seconds,
                     total_bank_seconds=banks * total_time,
                     area_mm2=ch.area_mm2)
    return OnlineResult(g, violations, stall, h)


def simulate_online_forecast(durations: np.ndarray, occupancy: np.ndarray, *,
                             capacity: int, banks: int,
                             cfg: Optional[ControllerConfig] = None,
                             fcfg: Optional[ForecastConfig] = None,
                             n_reads: int = 0, n_writes: int = 0,
                             char: Optional[SramCharacterization] = None
                             ) -> OnlineResult:
    """The timeout policy plus PSS-style affine pre-wake.

    Which idle runs get gated is identical to `simulate_online` (same
    hysteresis timer); the forecast only adds *speculative wakes inside
    gated runs*. While a bank sits gated, the controller fits the trailing
    occupancy trend with a causal affine least-squares extrapolator
    (:class:`repro.sim.pss.AffineForecaster` — the PSS affinity trick
    pointed at time) and anchors the forecast at the *currently observed*
    occupancy: demand is imminent at a boundary when the trend is rising
    and ``occ_now + slope x lead`` crosses the bank's demand threshold
    (``occ > b * alpha * capacity / banks``, exactly `bank_activity`'s
    cut). The bank is held awake exactly while that signal holds and
    re-gates the moment it drops — a false pre-wake therefore leaks only
    for the segments it persisted, not for the rest of the run. A run
    whose final approach the bank spent awake (woken at least
    `wake_latency_s` before demand returned) turns its on-demand wake
    violation into `early_wake_s` leakage: the forecast trades bounded
    early leakage (~``lead x leak_w_per_bank`` per avoided violation)
    against critical-path stalls.

    Energy ordering: oracle <= online <= forecast on leakage-only terms is
    NOT guaranteed (a bad forecast can speculatively wake for nothing),
    but the extra leakage is bounded by the signal-active seconds and every
    speculative wake costs one extra transition pair — both reported."""
    cfg = cfg or ControllerConfig()
    fcfg = fcfg or ForecastConfig()
    ch = char or characterize(capacity, banks)
    d = np.asarray(durations, np.float64)
    occ = np.asarray(occupancy, np.float64)
    total_time = float(d.sum())
    h = cfg.hysteresis_multiple * ch.break_even_s
    lead = fcfg.lead

    e_dyn = n_reads * ch.e_read_j + n_writes * ch.e_write_j

    act = bank_activity(occ, cfg.alpha, capacity, banks)
    on = bank_on_matrix(act, banks)
    cum = np.concatenate([[0.0], np.cumsum(d)])
    usable = cfg.alpha * capacity / banks

    # the fit inputs are bank-independent: evaluate the trend slope at
    # every segment boundary once, then per-bank wake tests are just
    # threshold compares against that bank's demand cut. The forecast is
    # anchored at the observed occupancy (not the fitted intercept, which
    # lags it right after a drop): occ_now + slope x lead.
    fc = AffineForecaster(cum[:-1], occ, fcfg.window_s)
    slopes = np.array([fc.slope(float(t)) for t in cum[:-1]])
    fvals = occ + np.maximum(slopes, 0.0) * lead

    on_seconds = 0.0
    gated_seconds = 0.0
    n_sw = 0
    violations = 0
    pre_wakes = 0
    early_s = 0.0
    for b in range(banks):
        col = on[:, b]
        on_seconds += float(d[col].sum())            # busy segments
        run_d, starts, ends = idle_runs(d, col)
        thresh = b * usable
        for r, s, e in zip(run_d, starts, ends):
            if r <= h:
                on_seconds += r       # timer never expires: leak it out
                continue
            t_s, t_e = float(cum[s]), float(cum[e])
            # speculative-wake decision points: boundaries in the gated
            # region; the bank is awake through segment k iff the signal
            # held at boundary k, and re-gates when it drops
            k0 = int(np.searchsorted(cum[: len(d)], t_s + h, side="left"))
            ks = np.arange(k0, e)
            sig = (slopes[ks] > 0) & (fvals[ks] > thresh) if len(ks) \
                else np.zeros(0, bool)
            awake_s = float(d[ks[sig]].sum()) if sig.any() else 0.0
            wakes = int(np.count_nonzero(sig[1:] & ~sig[:-1])
                        + (1 if len(sig) and sig[0] else 0))
            on_seconds += h + awake_s
            gated_seconds += (r - h) - awake_s
            early_s += awake_s
            pre_wakes += wakes
            # transition pairs: the initial gate-off/wake-on pair plus one
            # per extra speculative wake (an on-demand wake is saved when
            # the bank is already awake at the run's end)
            n_sw += max(wakes + (0 if len(sig) and sig[-1] else 1), 1)
            if e < len(d):
                # the violation is avoided only if the bank spent the final
                # approach awake, woken >= wake_latency_s before demand
                if len(sig) and sig[-1]:
                    j = len(sig) - 1
                    while j > 0 and sig[j - 1]:
                        j -= 1
                    if t_e - float(cum[ks[j]]) < cfg.wake_latency_s:
                        violations += 1
                else:
                    violations += 1

    stall = violations * cfg.wake_latency_s
    e_leak = ch.leak_w_per_bank * on_seconds
    e_sw = n_sw * ch.e_switch_j
    g = GatingResult(policy=(f"forecast(h={cfg.hysteresis_multiple:g}xBE,"
                             f"w={fcfg.window_s:g}s)"),
                     alpha=cfg.alpha, capacity=capacity, banks=banks,
                     e_dyn=e_dyn, e_leak=e_leak, e_sw=e_sw,
                     n_transitions=n_sw, gated_bank_seconds=gated_seconds,
                     total_bank_seconds=banks * total_time,
                     area_mm2=ch.area_mm2)
    return OnlineResult(g, violations, stall, h,
                        pre_wakes=pre_wakes, early_wake_s=early_s)


@dataclass
class ControllerComparison:
    """online (reactive) vs offline-oracle vs no-gating on the same
    trace/(C,B); optionally also the forecast controller leg."""
    online: OnlineResult
    oracle: GatingResult
    none: GatingResult
    forecast: Optional[OnlineResult] = None

    @property
    def online_vs_none_pct(self) -> float:
        return 100.0 * (self.online.e_total / self.none.e_total - 1.0)

    @property
    def online_vs_oracle_pct(self) -> float:
        return 100.0 * (self.online.e_total / self.oracle.e_total - 1.0)

    @property
    def forecast_vs_oracle_pct(self) -> float:
        if self.forecast is None:
            return float("nan")
        return 100.0 * (self.forecast.e_total / self.oracle.e_total - 1.0)

    @property
    def forecast_vs_none_pct(self) -> float:
        if self.forecast is None:
            return float("nan")
        return 100.0 * (self.forecast.e_total / self.none.e_total - 1.0)

    def format(self) -> str:
        o, g, n = self.online, self.oracle, self.none
        out = (f"E[mJ] none={n.e_total*1e3:.1f} "
               f"oracle={g.e_total*1e3:.1f} "
               f"online={o.e_total*1e3:.1f} "
               f"({self.online_vs_none_pct:+.1f}% vs none, "
               f"{self.online_vs_oracle_pct:+.1f}% vs oracle)  "
               f"wakes={o.wake_violations} stall={o.stall_s*1e6:.1f}us")
        if self.forecast is not None:
            f = self.forecast
            out += (f"\n  forecast={f.e_total*1e3:.1f}mJ "
                    f"({self.forecast_vs_oracle_pct:+.1f}% vs oracle)  "
                    f"wakes={f.wake_violations} stall={f.stall_s*1e6:.1f}us "
                    f"pre_wakes={f.pre_wakes} "
                    f"early={f.early_wake_s*1e3:.2f}ms")
        return out


def _offline_candidates(capacity: int, banks: int, cfg: ControllerConfig,
                        oracle_policy: Optional[Policy]) -> List[Candidate]:
    """The two offline legs of one comparison as engine candidates."""
    pol = oracle_policy or Policy(
        "oracle", cfg.alpha, gate=True,
        min_gate_multiple=cfg.hysteresis_multiple)
    return [
        Candidate(capacity, banks, pol.alpha,
                  "gate" if pol.gate else "none", pol.min_gate_multiple,
                  label=pol.name),
        Candidate(capacity, banks, cfg.alpha, "none", label="none"),
    ]


def compare(durations: np.ndarray, occupancy: np.ndarray, *,
            capacity: int, banks: int, n_reads: int, n_writes: int,
            cfg: Optional[ControllerConfig] = None,
            fcfg: Optional[ForecastConfig] = None,
            oracle_policy: Optional[Policy] = None,
            backend: str = "auto") -> ControllerComparison:
    """The paper-style three-way comparison at one (C, B) point.

    The oracle uses `min_gate_multiple == hysteresis_multiple` so both
    policies gate the same set of idle runs — the gap between them is then
    purely the leakage burned during the online timer. The offline legs run
    on the batched engine; grid sweeps should prefer `compare_grid`, which
    batches them across every (C, B) point in one call."""
    cfg = cfg or ControllerConfig()
    ch = characterize(capacity, banks)
    online = simulate_online(durations, occupancy, capacity=capacity,
                             banks=banks, n_reads=n_reads, n_writes=n_writes,
                             cfg=cfg, char=ch)
    fore = None
    if fcfg is not None:
        fore = simulate_online_forecast(
            durations, occupancy, capacity=capacity, banks=banks,
            n_reads=n_reads, n_writes=n_writes, cfg=cfg, fcfg=fcfg, char=ch)
    res = evaluate_candidates(
        durations, occupancy,
        _offline_candidates(capacity, banks, cfg, oracle_policy),
        n_reads=n_reads, n_writes=n_writes, backend=backend)
    return ControllerComparison(online, res.gating_result(0),
                                res.gating_result(1), forecast=fore)


def compare_grid(durations: np.ndarray, occupancy: np.ndarray, *,
                 points: Sequence[Tuple[int, int]], n_reads: int,
                 n_writes: int, cfg: Optional[ControllerConfig] = None,
                 fcfg: Optional[ForecastConfig] = None,
                 backend: str = "auto"
                 ) -> Dict[Tuple[int, int], ControllerComparison]:
    """Three-way comparisons for every (capacity, banks) point at once.

    Both offline legs of every point go through one batched
    `evaluate_candidates` call; the causal online controller (inherently
    sequential over the trace) still runs per point."""
    cfg = cfg or ControllerConfig()
    cands: List[Candidate] = []
    for cap, b in points:
        cands.extend(_offline_candidates(cap, b, cfg, None))
    res = evaluate_candidates(durations, occupancy, cands, n_reads=n_reads,
                              n_writes=n_writes, backend=backend)
    out: Dict[Tuple[int, int], ControllerComparison] = {}
    for i, (cap, b) in enumerate(points):
        online = simulate_online(durations, occupancy, capacity=cap, banks=b,
                                 n_reads=n_reads, n_writes=n_writes, cfg=cfg)
        fore = None
        if fcfg is not None:
            fore = simulate_online_forecast(
                durations, occupancy, capacity=cap, banks=b,
                n_reads=n_reads, n_writes=n_writes, cfg=cfg, fcfg=fcfg)
        out[(cap, b)] = ControllerComparison(
            online, res.gating_result(2 * i), res.gating_result(2 * i + 1),
            forecast=fore)
    return out
