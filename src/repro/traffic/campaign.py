"""Traffic campaigns: intensity x model x (C, B, policy) sweeps + Pareto.

Two evaluation paths over the same traffic-generated occupancy traces:

  * exact     — `controller.compare_grid`: the offline oracle and no-gating
                legs of every (C, B) point in one batched
                `core.candidates.evaluate_candidates` call, plus the causal
                online timeout controller per point (wake-latency
                violations included).
  * fast grid — per-candidate energy *lower bound* in one vectorized call
                (`core.candidates.lower_bound_energies`): dynamic energy +
                required-bank leakage only, which bounds every policy from
                below. With `prune=True` it cuts the (C, B) grid before the
                exact phase — the right objective for thousand-scenario
                campaigns; the true argmin is never dropped.

Traces are resampled onto a uniform grid before the fast path so every
scenario shares one padded segment shape (one compilation, batched sweep).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs import resolve_arch
from repro.core.candidates import lower_bound_energies, make_grid
from repro.core.explorer import DEFAULT_BANKS, MIB, min_capacity_mib  # noqa: F401 (re-exported)
from repro.traffic.controller import ControllerComparison, ControllerConfig, \
    ForecastConfig, compare, compare_grid
from repro.traffic.generators import LengthModel, generate, generate_workload
from repro.traffic.occupancy import TrafficSim, simulate_prefix_traffic, \
    simulate_spec_traffic, simulate_traffic, utilization_summary


@dataclass(frozen=True)
class Scenario:
    """One cell of the campaign grid (arch x traffic point).

    `workload` selects a shared-prefix family ("chat_sysprompt", "fewshot",
    "agentic_fanout") or "plain" for unstructured traffic. Shared workloads
    run through the page-granular prefix-sharing simulator; the (C, B) grid
    is then evaluated against *physical* occupancy — the logical trace
    rides along in the sim bundle for headroom reporting."""
    arch: str
    arrival: str = "poisson"
    rate: float = 4.0
    seed: int = 0
    horizon_s: float = 30.0
    num_slots: int = 8
    max_len: int = 2048
    workload: str = "plain"
    prefix_len: int = 512
    sharing: int = 8
    page_size: int = 16
    kv_dtype: str = "bf16"
    # speculative decoding (model-free spec simulator when speculate_k set)
    speculate_k: Optional[int] = None
    spec_acceptance: float = 0.7
    draft_kv_frac: float = 0.5

    @property
    def kv_dtype_bytes(self) -> int:
        """Data bytes per cached K/V element for the model-free sims.

        Scale overhead of the int8 pools is excluded here deliberately: the
        sims account data bytes only, so quantized traces stay exact
        integer ratios of the bf16 baseline (the golden-trace invariant)."""
        return {"fp32": 4, "bf16": 2, "fp16": 2, "int8": 1, "fp8": 1}[
            self.kv_dtype]

    @property
    def traffic_key(self) -> Tuple:
        """Scenarios sharing this key see byte-identical request streams."""
        return (self.arrival, self.rate, self.seed, self.horizon_s,
                self.workload, self.prefix_len, self.sharing,
                self.speculate_k, self.spec_acceptance, self.draft_kv_frac)


@dataclass
class CampaignRow:
    scenario: Scenario
    capacity_mib: int
    banks: int
    comparison: ControllerComparison
    peak_mib: float
    mean_mib: float
    p95_latency_s: float
    # serving SLO percentiles of the scenario's traffic (same for every
    # (C, B) row of one scenario — the grid reprices energy, not latency)
    ttft_p50_s: float = 0.0
    ttft_p99_s: float = 0.0
    tbt_p50_s: float = 0.0
    tbt_p99_s: float = 0.0
    # streaming BankEnergyMeter report for the scenario's metered candidate
    # (same for every (C, B) row of one scenario; None when no --meter)
    energy: Optional[object] = None

    @property
    def e_online(self) -> float:
        return self.comparison.online.e_total

    @property
    def e_oracle(self) -> float:
        return self.comparison.oracle.e_total

    @property
    def e_none(self) -> float:
        return self.comparison.none.e_total

    # first-class controller SLO columns: wake-latency violations and the
    # stall seconds they expose, reactive vs forecast leg
    @property
    def wakes_reactive(self) -> int:
        return self.comparison.online.wake_violations

    @property
    def stall_reactive_s(self) -> float:
        return self.comparison.online.stall_s

    @property
    def e_forecast(self) -> float:
        f = self.comparison.forecast
        return float("nan") if f is None else f.e_total

    @property
    def wakes_forecast(self) -> Optional[int]:
        f = self.comparison.forecast
        return None if f is None else f.wake_violations

    @property
    def stall_forecast_s(self) -> float:
        f = self.comparison.forecast
        return float("nan") if f is None else f.stall_s


@dataclass
class CampaignReport:
    rows: List[CampaignRow] = field(default_factory=list)
    fast_grid: Dict[Tuple, np.ndarray] = field(default_factory=dict)
    sims: Dict[Tuple, TrafficSim] = field(default_factory=dict)

    def best_per_scenario(self) -> List[CampaignRow]:
        best: Dict[Tuple, CampaignRow] = {}
        for r in self.rows:
            k = (r.scenario.arch, r.scenario.traffic_key)
            if k not in best or r.e_online < best[k].e_online:
                best[k] = r
        return list(best.values())

    def pareto_points(self) -> List[Tuple[float, float, str, int, int]]:
        """(area, online energy, arch, C, B) — the Fig.-9 scatter under
        traffic instead of a single inference."""
        return [(r.comparison.online.gating.area_mm2, r.e_online,
                 r.scenario.arch, r.capacity_mib, r.banks)
                for r in self.rows]

    def format(self) -> str:
        has_fc = any(r.comparison.forecast is not None for r in self.rows)
        head = (f"{'arch':>20} {'arrival':>8} {'rate':>5} {'C':>5} {'B':>3} "
                f"{'peak':>7} {'E_none':>8} {'E_oracle':>9} {'E_online':>9} "
                f"{'dNone%':>7} {'dOrcl%':>7} {'wakes':>6} {'stall_us':>8}")
        if has_fc:
            head += f" {'E_fcast':>9} {'dFOrcl%':>8} {'fwakes':>6} " \
                    f"{'fstall_us':>9}"
        head += (f" {'p95[s]':>7} {'ttft50':>7} {'ttft99':>7} "
                 f"{'tbt50':>8} {'tbt99':>8}")
        lines = [head]
        for r in self.rows:
            c = r.comparison
            line = (
                f"{r.scenario.arch:>20} {r.scenario.arrival:>8} "
                f"{r.scenario.rate:>5.1f} {r.capacity_mib:>5} {r.banks:>3} "
                f"{r.peak_mib:>6.1f}M {r.e_none*1e3:>8.1f} "
                f"{r.e_oracle*1e3:>9.1f} {r.e_online*1e3:>9.1f} "
                f"{c.online_vs_none_pct:>+7.1f} {c.online_vs_oracle_pct:>+7.1f} "
                f"{r.wakes_reactive:>6} {r.stall_reactive_s*1e6:>8.1f}")
            if has_fc:
                if c.forecast is not None:
                    line += (f" {r.e_forecast*1e3:>9.1f} "
                             f"{c.forecast_vs_oracle_pct:>+8.1f} "
                             f"{r.wakes_forecast:>6} "
                             f"{r.stall_forecast_s*1e6:>9.1f}")
                else:
                    line += f" {'-':>9} {'-':>8} {'-':>6} {'-':>9}"
            line += (f" {r.p95_latency_s:>7.2f} "
                     f"{r.ttft_p50_s:>7.3f} {r.ttft_p99_s:>7.3f} "
                     f"{r.tbt_p50_s:>8.4f} {r.tbt_p99_s:>8.4f}")
            lines.append(line)
        # streaming-meter footer: one block per metered scenario (J/request
        # percentiles, wake causes, per-tenant energy breakdown)
        seen = set()
        for r in self.rows:
            if r.energy is None:
                continue
            k = (r.scenario.arch, r.scenario.traffic_key)
            if k in seen:
                continue
            seen.add(k)
            lines.append(f"-- {r.scenario.arch} "
                         f"{r.scenario.arrival}@{r.scenario.rate:g}/s --")
            lines.append(r.energy.format())
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Vectorized fast path
# ---------------------------------------------------------------------------

def fast_candidate_energies(durations: np.ndarray, occupancy: np.ndarray, *,
                            capacities_mib: Sequence[int],
                            banks: Sequence[int], alpha: float,
                            n_reads: int, n_writes: int,
                            backend: str = "auto") -> np.ndarray:
    """Per-candidate energy lower bound for every (C, B) in one jit call.

    Returns shape (len(capacities) * len(banks),) J, ordered like
    `candidate_grid` (C-major): dynamic energy + leakage of the banks the
    occupancy *requires* per segment. Switch energy is deliberately excluded
    — charging it per idle run can exceed what any threshold policy pays on
    sub-break-even runs, which would break the bound. Without it the value
    is a true lower bound on `gating.evaluate` under every policy (required
    leakage and dynamic accesses are unavoidable, switching is >= 0), which
    is what makes it safe for pruning. Thin wrapper over the engine's
    `lower_bound_energies` — one implementation serves campaign pruning,
    `evaluate_candidates(prune=True)` and the sweep CLIs."""
    cands = make_grid([int(c * MIB) for c in capacities_mib], banks,
                      alphas=(alpha,))
    return lower_bound_energies(durations, occupancy, cands,
                                n_reads=n_reads, n_writes=n_writes,
                                backend=backend)


# ---------------------------------------------------------------------------
# Campaign driver
# ---------------------------------------------------------------------------

def run_scenario(scn: Scenario, *, capacities_mib: Optional[Sequence[int]],
                 banks: Sequence[int], ctrl: ControllerConfig,
                 fcfg: Optional[ForecastConfig] = None,
                 lengths: Optional[LengthModel] = None,
                 resample_dt: Optional[float] = None,
                 fast_backend: str = "auto",
                 backend: str = "auto", prune: bool = False,
                 prune_margin: float = 1e-3,
                 fidelity: str = "auto",
                 meter_spec: Optional[str] = None,
                 telemetry=None) -> Tuple[
                     TrafficSim, List[CampaignRow], np.ndarray]:
    """Simulate one scenario's traffic, then evaluate its (C, B) grid.

    Both offline legs of every (C, B) point run through one batched
    `compare_grid` call. With `prune=True`, the jit'd lower-bound grid cuts
    the candidate set first: a point survives only if its bound does not
    exceed the incumbent's exact online energy by `prune_margin` (relative);
    pruned points — which cannot win under any policy — get no rows."""
    from repro.obs.telemetry import noop_registry
    tel = telemetry if telemetry is not None else noop_registry()
    cfg = resolve_arch(scn.arch)
    lengths = lengths or LengthModel(max_len=scn.max_len)
    meter = None
    if meter_spec is not None:
        from repro.obs.energy import BankEnergyMeter
        meter = BankEnergyMeter.from_spec(meter_spec, telemetry=telemetry)
    with tel.span("campaign.simulate", arch=scn.arch, rate=scn.rate):
        if scn.speculate_k is not None:
            if scn.workload != "plain":
                raise ValueError(
                    "speculate_k only composes with workload='plain': the "
                    "model-free spec and prefix-sharing simulators are "
                    "separate channels")
            reqs = generate(scn.arrival, scn.rate, scn.horizon_s,
                            seed=scn.seed, lengths=lengths)
            sim = simulate_spec_traffic(
                cfg, reqs, num_slots=scn.num_slots,
                page_size=scn.page_size, max_len=scn.max_len,
                spec_k=scn.speculate_k, acceptance=scn.spec_acceptance,
                draft_kv_frac=scn.draft_kv_frac, seed=scn.seed,
                kv_dtype_bytes=scn.kv_dtype_bytes, meter=meter)
        elif scn.workload != "plain":
            reqs = generate_workload(scn.workload, scn.rate, scn.horizon_s,
                                     seed=scn.seed, lengths=lengths,
                                     arrival=scn.arrival,
                                     prefix_len=scn.prefix_len,
                                     sharing=scn.sharing, fanout=scn.sharing)
            sim = simulate_prefix_traffic(cfg, reqs, num_slots=scn.num_slots,
                                          page_size=scn.page_size,
                                          max_len=scn.max_len, seed=scn.seed,
                                          kv_dtype_bytes=scn.kv_dtype_bytes,
                                          meter=meter)
        else:
            reqs = generate(scn.arrival, scn.rate, scn.horizon_s,
                            seed=scn.seed, lengths=lengths)
            sim = simulate_traffic(cfg, reqs, num_slots=scn.num_slots,
                                   max_len=scn.max_len, fidelity=fidelity,
                                   kv_dtype_bytes=scn.kv_dtype_bytes,
                                   meter=meter)
    trace = sim.trace
    if resample_dt:
        trace = trace.resampled(resample_dt, sim.total_time)
    dur, occ = trace.occupancy_series(sim.total_time, use="needed")
    n_r = sim.bundle.access.n_reads("kv")
    n_w = sim.bundle.access.n_writes("kv")
    peak = trace.peak_needed()

    if capacities_mib is None:
        lo = max(min_capacity_mib(peak), 16)
        capacities_mib = sorted({lo, 2 * lo})

    with tel.span("campaign.fast_grid", arch=scn.arch,
                  n_points=len(capacities_mib) * len(banks)):
        fast = fast_candidate_energies(
            dur, occ, capacities_mib=list(capacities_mib), banks=list(banks),
            alpha=ctrl.alpha, n_reads=n_r, n_writes=n_w, backend=fast_backend)

    points = [(int(c_mib * MIB), b)
              for c_mib in capacities_mib for b in banks
              if int(c_mib * MIB) >= peak]
    precomputed = {}
    if prune and len(points) > 1:
        # fast grid is C-major over (capacities x banks), like `points`
        lb = {(int(c_mib * MIB), b): fast[i]
              for i, (c_mib, b) in enumerate(
                  (c, b) for c in capacities_mib for b in banks)}
        best = min(points, key=lambda p: lb[p])
        inc = compare(dur, occ, capacity=best[0], banks=best[1],
                      n_reads=n_r, n_writes=n_w, cfg=ctrl, fcfg=fcfg,
                      backend=backend)
        precomputed[best] = inc        # incumbent is already fully evaluated
        cutoff = inc.online.e_total * (1.0 + prune_margin)
        points = [p for p in points if lb[p] <= cutoff or p == best]

    with tel.span("campaign.compare_grid", arch=scn.arch,
                  n_points=len(points)):
        comparisons = compare_grid(
            dur, occ, points=[p for p in points if p not in precomputed],
            n_reads=n_r, n_writes=n_w, cfg=ctrl, fcfg=fcfg, backend=backend)
    comparisons.update(precomputed)
    util = utilization_summary(sim)
    energy_rep = None
    if meter is not None:
        # credit forecast-leg pre-wakes of the metered (C, B) point, when
        # that point was part of the compared grid
        mpoint = (meter.capacity, meter.banks)
        comp = comparisons.get(mpoint)
        if comp is not None and comp.forecast is not None:
            meter.note_prewake(comp.forecast.pre_wakes)
        energy_rep = meter.report(sim.total_time,
                                  n_reads=n_r, n_writes=n_w)
    rows = [CampaignRow(scn, cap // MIB, b, comparisons[(cap, b)],
                        peak_mib=util["peak_bytes"] / MIB,
                        mean_mib=util["mean_bytes"] / MIB,
                        p95_latency_s=util["p95_latency_s"],
                        ttft_p50_s=util["ttft_p50_s"],
                        ttft_p99_s=util["ttft_p99_s"],
                        tbt_p50_s=util["tbt_p50_s"],
                        tbt_p99_s=util["tbt_p99_s"],
                        energy=energy_rep)
            for cap, b in points]
    tel.counter("campaign.scenarios").inc()
    tel.counter("campaign.rows").inc(len(rows))
    return sim, rows, fast


def run_campaign(archs: Sequence[str], *, arrivals: Sequence[str] = ("poisson",),
                 rates: Sequence[float] = (4.0,), seeds: Sequence[int] = (0,),
                 horizon_s: float = 30.0, num_slots: int = 8,
                 max_len: int = 2048,
                 capacities_mib: Optional[Sequence[int]] = None,
                 banks: Sequence[int] = DEFAULT_BANKS,
                 ctrl: Optional[ControllerConfig] = None,
                 fcfg: Optional[ForecastConfig] = None,
                 lengths: Optional[LengthModel] = None,
                 resample_dt: Optional[float] = None,
                 fast_backend: str = "auto",
                 backend: str = "auto",
                 prune: bool = False,
                 fidelity: str = "auto",
                 workload: str = "plain",
                 prefix_len: int = 512,
                 sharing: int = 8,
                 page_size: int = 16,
                 kv_dtype: str = "bf16",
                 speculate_k: Optional[int] = None,
                 spec_acceptance: float = 0.7,
                 draft_kv_frac: float = 0.5,
                 meter_spec: Optional[str] = None,
                 telemetry=None) -> CampaignReport:
    """The full grid. Identical (arrival, rate, seed) cells share one request
    stream across architectures, so MHA-vs-GQA rows are directly comparable."""
    ctrl = ctrl or ControllerConfig()
    report = CampaignReport()
    for arrival in arrivals:
        for rate in rates:
            for seed in seeds:
                for arch in archs:
                    scn = Scenario(arch=arch, arrival=arrival, rate=rate,
                                   seed=seed, horizon_s=horizon_s,
                                   num_slots=num_slots, max_len=max_len,
                                   workload=workload, prefix_len=prefix_len,
                                   sharing=sharing, page_size=page_size,
                                   kv_dtype=kv_dtype,
                                   speculate_k=speculate_k,
                                   spec_acceptance=spec_acceptance,
                                   draft_kv_frac=draft_kv_frac)
                    sim, rows, fast = run_scenario(
                        scn, capacities_mib=capacities_mib, banks=banks,
                        ctrl=ctrl, fcfg=fcfg, lengths=lengths,
                        resample_dt=resample_dt,
                        fast_backend=fast_backend, backend=backend,
                        prune=prune, fidelity=fidelity,
                        meter_spec=meter_spec, telemetry=telemetry)
                    key = (arch, scn.traffic_key)
                    report.sims[key] = sim
                    report.rows.extend(rows)
                    report.fast_grid[key] = fast
    return report
