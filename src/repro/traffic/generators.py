"""Seeded serving-traffic generators: request arrival processes + lengths.

Production KV occupancy is driven by *load*, not by a single sequence's
length: requests arrive and finish at different times, so the on-chip KV
footprint fluctuates with concurrency — the regime where time-resolved
analysis (and therefore power gating) pays off most. Each generator here is a
pure function of its seed and emits a list of `RequestSpec`s; the same spec
list replayed against two architectures gives the MHA-vs-GQA comparison
under *identical* traffic.

Arrival processes:
  * "poisson"  — homogeneous Poisson(rate) over [0, horizon).
  * "bursty"   — 2-state Markov-modulated Poisson process (MMPP-2): calm and
                 burst states with different rates, exponential dwell times.
  * "diurnal"  — non-homogeneous Poisson with a sinusoidal rate profile
                 (one "day" compressed into `period_s`), drawn by thinning.
  * "replay"   — explicit arrival times (trace replay from a log).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclass(frozen=True)
class RequestSpec:
    """One request of a traffic trace: all times in seconds.

    `prefix_id`/`prefix_len` mark a shared prompt prefix: every request
    carrying the same `prefix_id` begins with the identical `prefix_len`
    leading tokens (materialized by `materialize_tokens`). Plain workloads
    leave them at None/0."""
    rid: int
    arrival_s: float
    prompt_len: int
    output_len: int
    prefix_id: Optional[int] = None
    prefix_len: int = 0


@dataclass(frozen=True)
class LengthModel:
    """Lognormal prompt / output token-length distributions, clamped to
    [min_len, max_len]. Defaults loosely follow public serving traces
    (short chatty prompts, a heavy tail of long generations)."""
    prompt_mean: float = 128.0
    prompt_sigma: float = 0.8        # sigma of underlying normal
    output_mean: float = 64.0
    output_sigma: float = 0.6
    min_len: int = 1
    max_len: int = 2048

    def draw(self, rng: np.random.Generator, n: int):
        def lognorm(mean, sigma):
            mu = np.log(mean) - 0.5 * sigma ** 2
            v = np.exp(rng.normal(mu, sigma, size=n))
            return np.clip(np.rint(v).astype(np.int64),
                           self.min_len, self.max_len)
        return lognorm(self.prompt_mean, self.prompt_sigma), \
            lognorm(self.output_mean, self.output_sigma)


def _specs(arrivals: np.ndarray, lengths: LengthModel,
           rng: np.random.Generator) -> List[RequestSpec]:
    arrivals = np.sort(np.asarray(arrivals, np.float64))
    p, o = lengths.draw(rng, len(arrivals))
    return [RequestSpec(rid=i, arrival_s=float(t), prompt_len=int(pi),
                        output_len=int(oi))
            for i, (t, pi, oi) in enumerate(zip(arrivals, p, o))]


# ---------------------------------------------------------------------------
# Arrival processes
# ---------------------------------------------------------------------------

def poisson(rate: float, horizon_s: float, *, seed: int = 0,
            lengths: Optional[LengthModel] = None) -> List[RequestSpec]:
    """Homogeneous Poisson arrivals at `rate` req/s over [0, horizon)."""
    rng = np.random.default_rng(seed)
    n = rng.poisson(rate * horizon_s)
    arrivals = rng.uniform(0.0, horizon_s, size=n)
    return _specs(arrivals, lengths or LengthModel(), rng)


def bursty(rate: float, horizon_s: float, *, seed: int = 0,
           burst_factor: float = 8.0, calm_dwell_s: float = 4.0,
           burst_dwell_s: float = 1.0,
           lengths: Optional[LengthModel] = None) -> List[RequestSpec]:
    """MMPP-2: calm state at `rate_calm`, burst state at
    `burst_factor * rate_calm`, with the calm rate chosen so the long-run
    mean equals `rate`. Exponential dwell times in each state."""
    rng = np.random.default_rng(seed)
    pi_burst = burst_dwell_s / (calm_dwell_s + burst_dwell_s)
    rate_calm = rate / (1 - pi_burst + pi_burst * burst_factor)
    arrivals: List[float] = []
    t, in_burst = 0.0, False
    while t < horizon_s:
        dwell = rng.exponential(burst_dwell_s if in_burst else calm_dwell_s)
        end = min(t + dwell, horizon_s)
        r = rate_calm * (burst_factor if in_burst else 1.0)
        n = rng.poisson(r * (end - t))
        arrivals.extend(rng.uniform(t, end, size=n))
        t, in_burst = end, not in_burst
    return _specs(np.asarray(arrivals), lengths or LengthModel(), rng)


def diurnal(rate: float, horizon_s: float, *, seed: int = 0,
            peak_to_trough: float = 4.0, period_s: Optional[float] = None,
            lengths: Optional[LengthModel] = None) -> List[RequestSpec]:
    """Non-homogeneous Poisson whose rate ramps sinusoidally between trough
    and peak (mean = `rate`), one full cycle per `period_s` (default: the
    horizon). Sampled exactly by thinning against the peak rate."""
    rng = np.random.default_rng(seed)
    period = period_s or horizon_s
    # mean of trough + (peak-trough) * (1+sin)/2 over a cycle is the midpoint
    trough = 2.0 * rate / (1.0 + peak_to_trough)
    peak = peak_to_trough * trough

    def lam(t):
        phase = 2 * np.pi * t / period
        return trough + (peak - trough) * 0.5 * (1 + np.sin(phase - np.pi / 2))

    n_cand = rng.poisson(peak * horizon_s)
    cand = rng.uniform(0.0, horizon_s, size=n_cand)
    keep = rng.uniform(0.0, peak, size=n_cand) < lam(cand)
    return _specs(cand[keep], lengths or LengthModel(), rng)


def replay(arrival_times_s: Sequence[float], *, seed: int = 0,
           prompt_lens: Optional[Sequence[int]] = None,
           output_lens: Optional[Sequence[int]] = None,
           lengths: Optional[LengthModel] = None) -> List[RequestSpec]:
    """Trace replay: explicit arrivals; lengths taken from the log when
    given, else drawn from the (seeded) length model."""
    rng = np.random.default_rng(seed)
    times = np.asarray(arrival_times_s, np.float64)
    if (prompt_lens is None) != (output_lens is None):
        raise ValueError("replay needs both prompt_lens and output_lens "
                         "(or neither)")
    if prompt_lens is not None:
        if not (len(times) == len(prompt_lens) == len(output_lens)):
            raise ValueError("replay arrays must have equal length")
        order = np.argsort(times, kind="stable")   # keep log pairing intact
        return [RequestSpec(i, float(times[j]), int(prompt_lens[j]),
                            int(output_lens[j]))
                for i, j in enumerate(order)]
    return _specs(times, lengths or LengthModel(), rng)


GENERATORS: Dict[str, object] = {
    "poisson": poisson,
    "bursty": bursty,
    "diurnal": diurnal,
}


def generate(arrival: str, rate: float, horizon_s: float, *, seed: int = 0,
             lengths: Optional[LengthModel] = None,
             **kwargs) -> List[RequestSpec]:
    """Dispatch by arrival-process name ("replay" needs `replay()` directly)."""
    if arrival not in GENERATORS:
        raise KeyError(f"unknown arrival process {arrival!r}; "
                       f"known: {sorted(GENERATORS)} (+ replay)")
    fn = GENERATORS[arrival]
    return fn(rate, horizon_s, seed=seed, lengths=lengths, **kwargs)


# ---------------------------------------------------------------------------
# Shared-prefix workload families
# ---------------------------------------------------------------------------
#
# Real traffic repeats long prompt prefixes across requests — chat system
# prompts, few-shot templates, agentic fan-out. Each family below draws
# arrivals from one of the processes above, then attaches prefix structure:
# `sharing` controls the expected number of requests per distinct prefix
# (sharing factor), the length knobs the shared-prefix length distribution.
# The specs carry (prefix_id, prefix_len) only; `materialize_tokens` turns
# them into concrete token arrays whose leading tokens actually coincide.

def _arrival_times(arrival: str, rate: float, horizon_s: float, seed: int,
                   **kw) -> np.ndarray:
    specs = generate(arrival, rate, horizon_s, seed=seed, **kw)
    return np.asarray([s.arrival_s for s in specs], np.float64)


def _family_rng(seed: int, tag: int) -> np.random.Generator:
    """Substream for a workload family's structure draws, keyed away from
    the bare `seed` the arrival process consumes — otherwise prefix
    lengths/assignments would be transforms of the very bits that set the
    arrival times (same PCG64 state)."""
    return np.random.default_rng([seed, 0x9E3779B9, tag])


def _clamped_lognorm(rng, mean: float, sigma: float, n: int, lo: int,
                     hi: int) -> np.ndarray:
    mu = np.log(max(mean, 1.0)) - 0.5 * sigma ** 2
    v = np.exp(rng.normal(mu, sigma, size=n))
    return np.clip(np.rint(v).astype(np.int64), lo, hi)


def _finish(arrivals, prefix_ids, prefix_lens, turn_lens, out_lens,
            max_len: int) -> List[RequestSpec]:
    order = np.argsort(arrivals, kind="stable")
    specs = []
    for i, j in enumerate(order):
        plen = int(prefix_lens[j]) + int(turn_lens[j])
        plen = min(plen, max_len)
        pfx = min(int(prefix_lens[j]), plen - 1)    # >= 1 unshared token
        specs.append(RequestSpec(
            rid=i, arrival_s=float(arrivals[j]), prompt_len=plen,
            output_len=int(out_lens[j]), prefix_id=int(prefix_ids[j]),
            prefix_len=max(pfx, 0)))
    return specs


def chat_sysprompt(rate: float, horizon_s: float, *, seed: int = 0,
                   lengths: Optional[LengthModel] = None,
                   arrival: str = "poisson", prefix_len: float = 512.0,
                   prefix_sigma: float = 0.25,
                   sharing: float = 8.0) -> List[RequestSpec]:
    """Multi-tenant chat: each tenant owns one system prompt (lognormal
    length around `prefix_len`); every request opens with its tenant's
    prompt followed by a per-request user turn. Expected requests per
    tenant == `sharing`."""
    lengths = lengths or LengthModel()
    rng = _family_rng(seed, 1)
    t = _arrival_times(arrival, rate, horizon_s, seed)
    n = len(t)
    n_tenants = max(1, int(round(n / max(sharing, 1.0))))
    tenant_pfx = _clamped_lognorm(rng, prefix_len, prefix_sigma, n_tenants,
                                  1, lengths.max_len - 1)
    tenant = rng.integers(0, n_tenants, size=n)
    turn, out = lengths.draw(rng, n)
    return _finish(t, tenant, tenant_pfx[tenant], turn, out, lengths.max_len)


def fewshot(rate: float, horizon_s: float, *, seed: int = 0,
            lengths: Optional[LengthModel] = None,
            arrival: str = "poisson", shots: int = 4,
            example_len: float = 128.0, example_sigma: float = 0.2,
            sharing: float = 8.0) -> List[RequestSpec]:
    """Few-shot templates: each template concatenates `shots` examples
    (lognormal length around `example_len`), shared by ~`sharing` requests;
    the per-request query is drawn from the length model."""
    lengths = lengths or LengthModel()
    rng = _family_rng(seed, 2)
    t = _arrival_times(arrival, rate, horizon_s, seed)
    n = len(t)
    n_tpl = max(1, int(round(n / max(sharing, 1.0))))
    tpl_pfx = np.stack([
        _clamped_lognorm(rng, example_len, example_sigma, shots, 1,
                         lengths.max_len // max(shots, 1)).sum()
        for _ in range(n_tpl)])
    tpl_pfx = np.clip(tpl_pfx, 1, lengths.max_len - 1)
    tpl = rng.integers(0, n_tpl, size=n)
    turn, out = lengths.draw(rng, n)
    return _finish(t, tpl, tpl_pfx[tpl], turn, out, lengths.max_len)


def agentic_fanout(rate: float, horizon_s: float, *, seed: int = 0,
                   lengths: Optional[LengthModel] = None,
                   arrival: str = "poisson", fanout: int = 8,
                   spread_s: float = 0.5, prefix_len: float = 512.0,
                   prefix_sigma: float = 0.4) -> List[RequestSpec]:
    """Agentic fan-out: parent tasks arrive at `rate / fanout`; each spawns
    `fanout` sub-requests within `spread_s` seconds, all sharing the
    parent's accumulated context as their prefix (sharing factor ==
    `fanout`, and the copies are nearly simultaneous — the hardest case
    for a non-sharing allocator)."""
    lengths = lengths or LengthModel()
    rng = _family_rng(seed, 3)
    parents = _arrival_times(arrival, rate / max(fanout, 1), horizon_s, seed)
    n_par = len(parents)
    par_pfx = _clamped_lognorm(rng, prefix_len, prefix_sigma, n_par, 1,
                               lengths.max_len - 1)
    t = np.repeat(parents, fanout) + rng.uniform(0.0, spread_s,
                                                 size=n_par * fanout)
    ids = np.repeat(np.arange(n_par), fanout)
    turn, out = lengths.draw(rng, n_par * fanout)
    return _finish(t, ids, par_pfx[ids], turn, out, lengths.max_len)


WORKLOADS: Dict[str, object] = {
    "chat_sysprompt": chat_sysprompt,
    "fewshot": fewshot,
    "agentic_fanout": agentic_fanout,
}


def generate_workload(workload: str, rate: float, horizon_s: float, *,
                      seed: int = 0, lengths: Optional[LengthModel] = None,
                      **kwargs) -> List[RequestSpec]:
    """Dispatch by workload-family name; "plain" falls through to the
    arrival-process dispatcher (no prefix structure)."""
    if workload == "plain":
        kwargs.pop("prefix_len", None)
        kwargs.pop("sharing", None)
        return generate(kwargs.pop("arrival", "poisson"), rate, horizon_s,
                        seed=seed, lengths=lengths, **kwargs)
    if workload not in WORKLOADS:
        raise KeyError(f"unknown workload {workload!r}; known: "
                       f"{sorted(WORKLOADS)} (+ plain)")
    fn = WORKLOADS[workload]
    # families expose different knobs (fewshot has shots, fanout has no
    # sharing, ...): drop the ones a family doesn't take so campaign/CLI
    # code can pass one uniform knob set
    import inspect
    accepted = set(inspect.signature(fn).parameters)
    kwargs = {k: v for k, v in kwargs.items() if k in accepted}
    return fn(rate, horizon_s, seed=seed, lengths=lengths, **kwargs)


def materialize_tokens(specs: Sequence[RequestSpec], vocab_size: int,
                       seed: int = 0) -> List[np.ndarray]:
    """Concrete token arrays for a spec list, aligned by position.

    Requests sharing a `prefix_id` get byte-identical leading
    `prefix_len` tokens (drawn once per group from a substream keyed by
    the id), followed by a per-request tail — deterministic in (seed,
    prefix_id, rid) regardless of list order."""
    group_len: Dict[int, int] = {}
    for s in specs:
        if s.prefix_id is not None:
            group_len[s.prefix_id] = max(group_len.get(s.prefix_id, 0),
                                         s.prefix_len)
    group_tok = {
        g: np.random.default_rng([seed, 1000003, g]).integers(
            0, vocab_size, size=n, dtype=np.int64)
        for g, n in group_len.items()}
    out = []
    for s in specs:
        tail_rng = np.random.default_rng([seed, 7919, s.rid])
        pfx = (group_tok[s.prefix_id][:s.prefix_len]
               if s.prefix_id is not None else
               np.zeros(0, np.int64))
        tail = tail_rng.integers(0, vocab_size,
                                 size=max(s.prompt_len - len(pfx), 0),
                                 dtype=np.int64)
        out.append(np.concatenate([pfx, tail]))
    return out
