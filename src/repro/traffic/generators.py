"""Seeded serving-traffic generators: request arrival processes + lengths.

Production KV occupancy is driven by *load*, not by a single sequence's
length: requests arrive and finish at different times, so the on-chip KV
footprint fluctuates with concurrency — the regime where time-resolved
analysis (and therefore power gating) pays off most. Each generator here is a
pure function of its seed and emits a list of `RequestSpec`s; the same spec
list replayed against two architectures gives the MHA-vs-GQA comparison
under *identical* traffic.

Arrival processes:
  * "poisson"  — homogeneous Poisson(rate) over [0, horizon).
  * "bursty"   — 2-state Markov-modulated Poisson process (MMPP-2): calm and
                 burst states with different rates, exponential dwell times.
  * "diurnal"  — non-homogeneous Poisson with a sinusoidal rate profile
                 (one "day" compressed into `period_s`), drawn by thinning.
  * "replay"   — explicit arrival times (trace replay from a log).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclass(frozen=True)
class RequestSpec:
    """One request of a traffic trace: all times in seconds."""
    rid: int
    arrival_s: float
    prompt_len: int
    output_len: int


@dataclass(frozen=True)
class LengthModel:
    """Lognormal prompt / output token-length distributions, clamped to
    [min_len, max_len]. Defaults loosely follow public serving traces
    (short chatty prompts, a heavy tail of long generations)."""
    prompt_mean: float = 128.0
    prompt_sigma: float = 0.8        # sigma of underlying normal
    output_mean: float = 64.0
    output_sigma: float = 0.6
    min_len: int = 1
    max_len: int = 2048

    def draw(self, rng: np.random.Generator, n: int):
        def lognorm(mean, sigma):
            mu = np.log(mean) - 0.5 * sigma ** 2
            v = np.exp(rng.normal(mu, sigma, size=n))
            return np.clip(np.rint(v).astype(np.int64),
                           self.min_len, self.max_len)
        return lognorm(self.prompt_mean, self.prompt_sigma), \
            lognorm(self.output_mean, self.output_sigma)


def _specs(arrivals: np.ndarray, lengths: LengthModel,
           rng: np.random.Generator) -> List[RequestSpec]:
    arrivals = np.sort(np.asarray(arrivals, np.float64))
    p, o = lengths.draw(rng, len(arrivals))
    return [RequestSpec(rid=i, arrival_s=float(t), prompt_len=int(pi),
                        output_len=int(oi))
            for i, (t, pi, oi) in enumerate(zip(arrivals, p, o))]


# ---------------------------------------------------------------------------
# Arrival processes
# ---------------------------------------------------------------------------

def poisson(rate: float, horizon_s: float, *, seed: int = 0,
            lengths: Optional[LengthModel] = None) -> List[RequestSpec]:
    """Homogeneous Poisson arrivals at `rate` req/s over [0, horizon)."""
    rng = np.random.default_rng(seed)
    n = rng.poisson(rate * horizon_s)
    arrivals = rng.uniform(0.0, horizon_s, size=n)
    return _specs(arrivals, lengths or LengthModel(), rng)


def bursty(rate: float, horizon_s: float, *, seed: int = 0,
           burst_factor: float = 8.0, calm_dwell_s: float = 4.0,
           burst_dwell_s: float = 1.0,
           lengths: Optional[LengthModel] = None) -> List[RequestSpec]:
    """MMPP-2: calm state at `rate_calm`, burst state at
    `burst_factor * rate_calm`, with the calm rate chosen so the long-run
    mean equals `rate`. Exponential dwell times in each state."""
    rng = np.random.default_rng(seed)
    pi_burst = burst_dwell_s / (calm_dwell_s + burst_dwell_s)
    rate_calm = rate / (1 - pi_burst + pi_burst * burst_factor)
    arrivals: List[float] = []
    t, in_burst = 0.0, False
    while t < horizon_s:
        dwell = rng.exponential(burst_dwell_s if in_burst else calm_dwell_s)
        end = min(t + dwell, horizon_s)
        r = rate_calm * (burst_factor if in_burst else 1.0)
        n = rng.poisson(r * (end - t))
        arrivals.extend(rng.uniform(t, end, size=n))
        t, in_burst = end, not in_burst
    return _specs(np.asarray(arrivals), lengths or LengthModel(), rng)


def diurnal(rate: float, horizon_s: float, *, seed: int = 0,
            peak_to_trough: float = 4.0, period_s: Optional[float] = None,
            lengths: Optional[LengthModel] = None) -> List[RequestSpec]:
    """Non-homogeneous Poisson whose rate ramps sinusoidally between trough
    and peak (mean = `rate`), one full cycle per `period_s` (default: the
    horizon). Sampled exactly by thinning against the peak rate."""
    rng = np.random.default_rng(seed)
    period = period_s or horizon_s
    # mean of trough + (peak-trough) * (1+sin)/2 over a cycle is the midpoint
    trough = 2.0 * rate / (1.0 + peak_to_trough)
    peak = peak_to_trough * trough

    def lam(t):
        phase = 2 * np.pi * t / period
        return trough + (peak - trough) * 0.5 * (1 + np.sin(phase - np.pi / 2))

    n_cand = rng.poisson(peak * horizon_s)
    cand = rng.uniform(0.0, horizon_s, size=n_cand)
    keep = rng.uniform(0.0, peak, size=n_cand) < lam(cand)
    return _specs(cand[keep], lengths or LengthModel(), rng)


def replay(arrival_times_s: Sequence[float], *, seed: int = 0,
           prompt_lens: Optional[Sequence[int]] = None,
           output_lens: Optional[Sequence[int]] = None,
           lengths: Optional[LengthModel] = None) -> List[RequestSpec]:
    """Trace replay: explicit arrivals; lengths taken from the log when
    given, else drawn from the (seeded) length model."""
    rng = np.random.default_rng(seed)
    times = np.asarray(arrival_times_s, np.float64)
    if (prompt_lens is None) != (output_lens is None):
        raise ValueError("replay needs both prompt_lens and output_lens "
                         "(or neither)")
    if prompt_lens is not None:
        if not (len(times) == len(prompt_lens) == len(output_lens)):
            raise ValueError("replay arrays must have equal length")
        order = np.argsort(times, kind="stable")   # keep log pairing intact
        return [RequestSpec(i, float(times[j]), int(prompt_lens[j]),
                            int(output_lens[j]))
                for i, j in enumerate(order)]
    return _specs(times, lengths or LengthModel(), rng)


GENERATORS: Dict[str, object] = {
    "poisson": poisson,
    "bursty": bursty,
    "diurnal": diurnal,
}


def generate(arrival: str, rate: float, horizon_s: float, *, seed: int = 0,
             lengths: Optional[LengthModel] = None,
             **kwargs) -> List[RequestSpec]:
    """Dispatch by arrival-process name ("replay" needs `replay()` directly)."""
    if arrival not in GENERATORS:
        raise KeyError(f"unknown arrival process {arrival!r}; "
                       f"known: {sorted(GENERATORS)} (+ replay)")
    fn = GENERATORS[arrival]
    return fn(rate, horizon_s, seed=seed, lengths=lengths, **kwargs)
