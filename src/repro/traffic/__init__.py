"""Serving-traffic subsystem: multi-tenant KV occupancy as a Stage-I workload.

`generators` draws seeded request streams, `occupancy` composes them into
time-resolved occupancy traces (Stage-II compatible via `sim.trace.TraceBundle`),
`controller` runs the online power-gating policy against the live trace, and
`campaign` sweeps traffic intensity x model x (C, B) grids.
"""
from repro.traffic.generators import (LengthModel, RequestSpec, bursty,  # noqa: F401
                                      diurnal, generate, poisson, replay)
from repro.traffic.occupancy import (SpecTrafficStats, TimingModel,  # noqa: F401
                                     TrafficSim, TrafficStats,
                                     simulate_spec_traffic, simulate_traffic,
                                     utilization_summary)
from repro.traffic.controller import (ControllerComparison,  # noqa: F401
                                      ControllerConfig, OnlineResult, compare,
                                      compare_grid, simulate_online)
from repro.traffic.campaign import (CampaignReport, CampaignRow,  # noqa: F401
                                    Scenario, fast_candidate_energies,
                                    run_campaign, run_scenario)
