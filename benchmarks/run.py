# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV and appends the run's headline metrics to BENCH_history.jsonl (override
# with --history PATH, disable with --no-history); `scripts/bench_gate.py`
# turns that history into a CI regression gate.
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

from benchmarks import kernels_bench, paper_figs, prefix_bench, \
    quant_bench, serve_bench, sla_bench, spec_bench, stage1_bench, \
    stage2_bench, traffic_bench

BENCHES = [
    ("fig1_mha_vs_gqa", paper_figs.fig1_mha_vs_gqa),
    ("fig5_occupancy", paper_figs.fig5_occupancy),
    ("fig6_latency_breakdown", paper_figs.fig6_latency_breakdown),
    ("fig7_energy_breakdown", paper_figs.fig7_energy_breakdown),
    ("fig8_bank_activity", paper_figs.fig8_bank_activity),
    ("table2_banking_sweep", paper_figs.table2_banking_sweep),
    ("table3_multilevel", paper_figs.table3_multilevel),
    ("fig9_energy_area", paper_figs.fig9_energy_area),
    ("beyond_all_archs", paper_figs.beyond_all_archs),
    ("beyond_scheduler", paper_figs.beyond_scheduler),
    ("traffic_trace", traffic_bench.bench_traffic_trace),
    ("traffic_fast_grid", traffic_bench.bench_traffic_fast_grid),
    ("stage1_pss", stage1_bench.bench_stage1_pss),
    ("stage2_engine", stage2_bench.bench_stage2_engine),
    ("serve_paged", serve_bench.bench_serve_paged),
    ("serve_prefix", prefix_bench.bench_serve_prefix),
    ("serve_quant", quant_bench.bench_serve_quant),
    ("serve_sla", sla_bench.bench_serve_sla),
    ("serve_spec", spec_bench.bench_serve_spec),
    ("kern_flash_attention", kernels_bench.bench_flash_attention),
    ("kern_gqa_decode", kernels_bench.bench_gqa_decode),
    ("kern_int8_matmul", kernels_bench.bench_int8_matmul),
    ("kern_bank_energy", kernels_bench.bench_bank_energy),
]


def append_history(path: str, results: dict) -> None:
    """One JSONL entry per run: every suite's headline us_per_call, in the
    key shape `scripts/bench_gate.py` guards (lower is better)."""
    entry = {"ts": time.strftime("%Y-%m-%dT%H:%M:%S", time.gmtime()),
             "source": "benchmarks.run",
             "metrics": {f"{name}.us_per_call": us
                         for name, us in results.items() if us > 0}}
    with open(path, "a") as f:
        f.write(json.dumps(entry) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("only", nargs="?", default=None,
                    help="substring filter over bench names")
    ap.add_argument("--history", default="BENCH_history.jsonl")
    ap.add_argument("--no-history", action="store_true")
    args = ap.parse_args()
    only = args.only
    print("name,us_per_call,derived")
    failures = 0
    results: dict = {}
    for name, fn in BENCHES:
        if only and only not in name:
            continue
        try:
            us, derived = fn()
            results[name] = us
            print(f"{name},{us:.0f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},-1,FAILED {type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if results and not args.no_history:
        append_history(args.history, results)
        print(f"# appended {len(results)} headline metrics to "
              f"{args.history}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
