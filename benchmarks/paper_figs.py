"""One benchmark per paper table/figure. Each returns (us_per_call, derived)."""
from __future__ import annotations

from dataclasses import replace

import numpy as np

from benchmarks.common import sim_workload, timed
from repro.configs import ASSIGNED_ARCHS, get_arch
from repro.core.energy import assemble_energy
from repro.core.explorer import alpha_sensitivity, min_capacity_mib, pareto_points, sweep
from repro.core.gating import bank_timeline
from repro.core.workload import build_graph
from repro.sim.accelerator import baseline_accelerator
from repro.sim.engine import simulate

MIB = 2**20


def fig1_mha_vs_gqa():
    """Fig. 1: iso-backbone MHA vs GQA energy/latency (paper: 2.89x / 3.14x).

    Same DS-R1D backbone, attention switched between MHA (kv = H = 12) and
    GQA (kv = 2). The regime is batched token GENERATION (decode) — where the
    KV cache traffic, proportional to the kv-head count, dominates."""
    from repro.core.workload import build_decode_graph
    base = get_arch("dsr1d-qwen-1.5b")
    mha = replace(base, name="dsr1d-mha-variant", num_kv_heads=base.num_heads)

    def run():
        a = baseline_accelerator(128)
        g_m = build_decode_graph(mha, context_len=2048, batch=16)
        g_g = build_decode_graph(base, context_len=2048, batch=16)
        rm, rg = simulate(g_m, a), simulate(g_g, a)
        em = assemble_energy(rm, a).total
        eg = assemble_energy(rg, a).total
        return em / eg, rm.total_time / rg.total_time

    (e_ratio, t_ratio), us = timed(run)
    return us, (f"decode energy_ratio={e_ratio:.2f}(paper2.89) "
                f"latency_ratio={t_ratio:.2f}(paper3.14)")


def fig5_occupancy():
    """Fig. 5 + C1/C2/C6: peaks, end-to-end times, 64-vs-128 MiB delta."""
    def run():
        gpt, _ = sim_workload("gpt2-xl", 128)
        ds, _ = sim_workload("dsr1d-qwen-1.5b", 128)
        ds64, _ = sim_workload("dsr1d-qwen-1.5b", 64)
        return gpt, ds, ds64

    (gpt, ds, ds64), us = timed(run)
    pk_g = gpt.peak_needed() / MIB
    pk_d = ds.peak_needed() / MIB
    return us, (f"peak_gpt={pk_g:.1f}MiB(paper107.3) "
                f"peak_ds={pk_d:.1f}MiB(paper39.1) "
                f"ratio={pk_g/pk_d:.2f}(paper2.72) "
                f"t_gpt={gpt.total_time*1e3:.1f}ms(paper593.9) "
                f"t_ds={ds.total_time*1e3:.1f}ms(paper313.6) "
                f"dt_64v128={abs(ds64.total_time-ds.total_time)*1e3:.2f}ms"
                f"(paper1.48)")


def fig6_latency_breakdown():
    """Fig. 6: per-op compute vs memory vs idle decomposition."""
    def run():
        out = {}
        for w in ("gpt2-xl", "dsr1d-qwen-1.5b"):
            sim, _ = sim_workload(w, 128)
            tot_c = sum(sim.ops.compute.values())
            tot_m = sum(sim.ops.memory.values())
            out[w] = tot_m / max(tot_c, 1e-12)
        return out

    ratios, us = timed(run)
    return us, (f"mem/compute_gpt={ratios['gpt2-xl']:.2f} "
                f"mem/compute_ds={ratios['dsr1d-qwen-1.5b']:.2f} "
                f"(paper: GPT-2 XL shows the larger memory/idle fraction)")


def fig7_energy_breakdown():
    """Fig. 7 + C3: on-chip energy and average PE utilization."""
    def run():
        out = {}
        for w in ("gpt2-xl", "dsr1d-qwen-1.5b"):
            sim, accel = sim_workload(w, 128)
            out[w] = (assemble_energy(sim, accel).total,
                      sim.pe_utilization, sim.busy_fraction)
        return out

    r, us = timed(run)
    eg, ug, bg = r["gpt2-xl"]
    ed, ud, bd = r["dsr1d-qwen-1.5b"]
    return us, (f"E_gpt={eg:.1f}J(paper78.47) E_ds={ed:.1f}J(paper40.52) "
                f"macutil_gpt={ug*100:.0f}% macutil_ds={ud*100:.0f}% "
                f"busy_gpt={bg*100:.0f}%(paper~38) busy_ds={bd*100:.0f}%"
                f"(paper~77)")


def fig8_bank_activity():
    """Fig. 8: bank-activity timeline for DS @64 MiB, B=4, alpha sweep."""
    def run():
        sim, _ = sim_workload("dsr1d-qwen-1.5b", 64)
        tr = sim.traces["sram"]
        dur, occ = tr.occupancy_series(sim.total_time, use="needed")
        stats = {}
        for a in (1.0, 0.9, 0.75, 0.5):
            tl = bank_timeline(dur, occ, capacity=64 * MIB, banks=4, alpha=a)
            mean_act = float((tl["active_banks"] * dur).sum() / dur.sum())
            stats[a] = mean_act
        return stats

    stats, us = timed(run)
    s = " ".join(f"a{a}={v:.2f}" for a, v in stats.items())
    return us, (f"mean_active_banks(B=4): {s} "
                f"(smaller alpha -> more active banks, paper Fig. 8)")


def table2_banking_sweep():
    """Table II: (C x B) energy/area sweep for both workloads at alpha=0.9."""
    def run():
        ds, _ = sim_workload("dsr1d-qwen-1.5b", 128)
        gpt, _ = sim_workload("gpt2-xl", 160)        # write-back-free trace
        t_ds = sweep(ds, capacities_mib=[64, 80, 96, 112, 128])
        t_gpt = sweep(gpt, capacities_mib=[112, 128])
        return t_ds, t_gpt

    (t_ds, t_gpt), us = timed(run)
    b_ds = t_ds.best()
    b_gpt = t_gpt.best()
    ds128 = [r for r in t_ds.rows if r.capacity_mib == 128]
    gpt128 = [r for r in t_gpt.rows if r.capacity_mib == 128]
    best_dE_ds = min(r.delta_e_pct for r in ds128)
    best_dE_gpt = min(r.delta_e_pct for r in gpt128)
    return us, (f"best_ds=C{b_ds.capacity_mib}/B{b_ds.banks} "
                f"dE128_ds={best_dE_ds:.1f}%(paper-61.3) "
                f"best_gpt=C{b_gpt.capacity_mib}/B{b_gpt.banks} "
                f"dE128_gpt={best_dE_gpt:.1f}%(paper-55.8) "
                f"gqa_advantage={best_dE_gpt-best_dE_ds:.1f}pp(paper~20)")


def table3_multilevel():
    """Table III: multi-level hierarchy (shared SRAM + DM1 + DM2), DS only."""
    def run():
        sim, _ = sim_workload("dsr1d-qwen-1.5b", 64, multilevel=True)
        base, _ = sim_workload("dsr1d-qwen-1.5b", 128)
        rows = {}
        for mem in ("sram", "dm1", "dm2"):
            t = sweep(sim, mem_name=mem, capacities_mib=[48, 64],
                      banks=(1, 4, 8, 16))
            rows[mem] = min(r.delta_e_pct for r in t.rows)
        return sim, base, rows

    (sim, base, rows), us = timed(run)
    peaks = {m: sim.traces[m].peak_needed() / MIB
             for m in ("sram", "dm1", "dm2")}
    return us, (f"peaks sram={peaks['sram']:.1f}/dm1={peaks['dm1']:.1f}/"
                f"dm2={peaks['dm2']:.1f}MiB(paper34.1/35.5/37.7) "
                f"bestdE sram={rows['sram']:.1f}%(paper-77.8) "
                f"dm1={rows['dm1']:.1f}%(paper-72.4) "
                f"dm2={rows['dm2']:.1f}%(paper-69.8) "
                f"t={sim.total_time*1e3:.0f}ms>t_base={base.total_time*1e3:.0f}ms"
                f"(paper550>313.6)")


def fig9_energy_area():
    """Fig. 9: energy-area scatter over all (C,B) candidates."""
    def run():
        ds, _ = sim_workload("dsr1d-qwen-1.5b", 128)
        gpt, _ = sim_workload("gpt2-xl", 160)
        t_ds = sweep(ds, capacities_mib=[64, 80, 96, 112, 128])
        t_gpt = sweep(gpt, capacities_mib=[112, 128])
        return pareto_points([t_ds, t_gpt])

    pts, us = timed(run)
    ds_pts = [(a, e) for a, e, w, c, b in pts if "dsr1d" in w]
    gpt_pts = [(a, e) for a, e, w, c, b in pts if "gpt2" in w]
    return us, (f"candidates={len(pts)} "
                f"minE_ds={min(e for _, e in ds_pts):.1f}J "
                f"minE_gpt={min(e for _, e in gpt_pts):.1f}J "
                f"(GQA curve strictly below MHA, paper Fig. 9)")


def beyond_scheduler():
    """Beyond-paper: occupancy-aware ('mempeak') scheduling. Among ready ops
    prefer the one with the smallest net SRAM growth — scores drain before new
    ones are produced. Peak SRAM drops ~50-60%, shrinking the minimum feasible
    capacity (and hence leakage), at a latency cost the TRAPTI flow prices
    end-to-end: E = E_dyn + P_leak(C_min) * T + gating."""
    def run():
        out = {}
        for name, cap in (("gpt2-xl", 160), ("dsr1d-qwen-1.5b", 128)):
            g = build_graph(get_arch(name), M=2048, subops=4)
            a = baseline_accelerator(cap)
            res = {}
            for pol in ("fifo", "mempeak"):
                sim = simulate(g, a, policy=pol)
                lo = min_capacity_mib(sim.traces["sram"].peak_needed())
                t = sweep(sim, capacities_mib=[lo])
                res[pol] = (sim.traces["sram"].peak_needed() / MIB,
                            sim.total_time, t.best().result.e_total)
            out[name] = res
        return out

    out, us = timed(run)
    parts = []
    for name, res in out.items():
        pf, mf = res["fifo"], res["mempeak"]
        parts.append(f"{name.split('-')[0]}: peak {pf[0]:.0f}->{mf[0]:.0f}MiB "
                     f"T {pf[1]*1e3:.0f}->{mf[1]*1e3:.0f}ms "
                     f"bestE {pf[2]:.1f}->{mf[2]:.1f}J "
                     f"({(mf[2]/pf[2]-1)*100:+.0f}%)")
    return us, " | ".join(parts)


def beyond_all_archs():
    """Beyond-paper: TRAPTI Stage I+II applied to all 10 assigned archs."""
    def run():
        rows = {}
        for a in ASSIGNED_ARCHS:
            sim, _ = sim_workload(a, 128)
            # round the peak UP to the 16 MiB grid (tinyllama's peak is
            # capacity-clamped slightly above 128)
            lo = min_capacity_mib(sim.traces["sram"].peak_needed())
            t = sweep(sim, capacities_mib=[lo], max_capacity_mib=max(lo, 128),
                      banks=(1, 8, 16))
            rows[a] = (sim.traces["sram"].peak_needed() / MIB,
                       min(r.delta_e_pct for r in t.rows))
        return rows

    rows, us = timed(run)
    s = " ".join(f"{a.split('-')[0]}:{p:.0f}MiB/{d:.0f}%"
                 for a, (p, d) in rows.items())
    return us, s
