"""Serving-path benchmark: paged device-resident decode vs the pre-PR
per-token host loop.

Two measurements on the reduced dsr1d config:

  * baseline — the decode loop `BatchedServer.generate` shipped before the
    paged refactor: one jitted `decode_step` per token, with a host sync
    (np.asarray) after every step;
  * paged — the `PagedContinuousBatcher` hot path: the same number of
    decode tokens through the paged cache, `chunk_steps` tokens per jitted
    donated `lax.scan` call, one host sync per chunk.

Also checks the paged GQA kernel (interpret mode) against the jnp reference
on a ragged page-table batch, and asserts the >=5x decode-throughput bar.
Writes `BENCH_serve.json`.

Run:  PYTHONPATH=src python -m benchmarks.serve_bench [out.json]
"""
from __future__ import annotations

import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import build_model
from repro.serve import PagedContinuousBatcher, Request
from repro.serve.paged import pages_for

DEFAULT_OUT = "BENCH_serve.json"
SPEEDUP_BAR = 5.0
TELEMETRY_OVERHEAD_BAR_PCT = 3.0
METER_OVERHEAD_BAR_PCT = 5.0


def _legacy_decode_tok_s(model, params, prompts: np.ndarray,
                         n_new: int) -> float:
    """The pre-PR BatchedServer.generate loop, verbatim: one jitted
    decode_step dispatch per token, an unjitted host-driven sample (rng
    split + argmax), and a np.asarray host sync after every step."""
    decode = jax.jit(model.decode_step)
    prefill = jax.jit(lambda p, b: model.prefill(
        p, b, cache_len=prompts.shape[1] + n_new + 8))

    def sample(logits, _rng):
        return jnp.argmax(logits[:, -1, :],
                          axis=-1)[:, None].astype(jnp.int32)

    def run():
        rng = jax.random.PRNGKey(0)
        logits, cache = prefill(params, {"tokens": jnp.asarray(prompts)})
        logits.block_until_ready()
        rng, k = jax.random.split(rng)
        tok = sample(logits, k)
        out = [np.asarray(tok)]
        t0 = time.perf_counter()
        for _ in range(n_new - 1):
            logits, cache = decode(params, cache, tok)
            rng, k = jax.random.split(rng)
            tok = sample(logits, k)
            out.append(np.asarray(tok))          # per-token host sync
        jax.block_until_ready(tok)
        return time.perf_counter() - t0

    run()                                        # warm compile
    dt = min(run() for _ in range(3))
    return (n_new - 1) * prompts.shape[0] / dt


def _paged_run_fn(model, params, prompts: np.ndarray, n_new: int,
                  page_size: int, chunk_steps: int, telemetry=None,
                  kv_dtype: str = "native", collect_logits: bool = False,
                  meter=None):
    """(timed-run closure, batcher) for the paged chunk loop; one call
    decodes every slot to completion and returns the decode seconds
    (prefills untimed)."""
    B, S = prompts.shape
    worst = pages_for(S + n_new, page_size)
    cb = PagedContinuousBatcher(
        model, params, num_slots=B, page_size=page_size,
        num_pages=B * worst + 8, max_pages_per_slot=worst + 1,
        chunk_steps=chunk_steps, attn_backend="ref", telemetry=telemetry,
        kv_dtype=kv_dtype, collect_logits=collect_logits, meter=meter)

    def run():
        for i in range(B):
            cb.submit(Request(rid=i, tokens=prompts[i],
                              max_new_tokens=n_new))
        done: list = []
        cb._admit(done)
        t0 = time.perf_counter()
        while any(s is not None for s in cb.slots):
            cb._decode_chunk(done)
        dt = time.perf_counter() - t0
        assert len(done) == B
        return dt

    return run, cb


def _paged_decode_tok_s(model, params, prompts: np.ndarray, n_new: int,
                        page_size: int, chunk_steps: int,
                        telemetry=None) -> tuple:
    """Decode tokens/s through the paged chunk loop (prefills untimed)."""
    run, cb = _paged_run_fn(model, params, prompts, n_new, page_size,
                            chunk_steps, telemetry)
    run()                                        # warm compile
    dt = min(run() for _ in range(3))
    return (n_new - 1) * prompts.shape[0] / dt, cb


def _kernel_exactness() -> float:
    """Max abs error, Pallas interpret vs jnp reference, ragged pages."""
    from repro.kernels.paged_gqa_decode import (paged_gqa_decode,
                                                paged_gqa_decode_ref)
    rng = np.random.default_rng(0)
    B, H, K, d, ps, P, N = 4, 12, 2, 64, 16, 4, 24
    q = jnp.asarray(rng.normal(size=(B, H, d)), jnp.float32)
    pk = jnp.asarray(rng.normal(size=(N, K, ps, d)), jnp.float32)
    pv = jnp.asarray(rng.normal(size=(N, K, ps, d)), jnp.float32)
    lengths = np.array([1, 16, 37, 64], np.int32)
    pt = np.zeros((B, P), np.int64)
    ids = list(range(1, N))
    rng.shuffle(ids)
    for b in range(B):
        for j in range(-(-int(lengths[b]) // ps)):
            pt[b, j] = ids.pop()
    pt, lengths = jnp.asarray(pt, jnp.int32), jnp.asarray(lengths)
    out = paged_gqa_decode(q, pk, pv, pt, lengths, backend="interpret")
    ref = paged_gqa_decode_ref(q, pk, pv, pt, lengths)
    return float(jnp.abs(out - ref).max())


def bench_serve(out_path: str = DEFAULT_OUT):
    cfg = reduced(get_arch("dsr1d-qwen-1.5b"), layers=2)
    model = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, prompt_len, n_new = 4, 32, 128
    prompts = rng.integers(0, cfg.vocab_size, (B, prompt_len)).astype(np.int32)

    err = _kernel_exactness()
    assert err < 2e-5, f"paged kernel vs reference: max abs err {err:.2e}"

    base_tok_s = _legacy_decode_tok_s(model, params, prompts, n_new)
    paged_tok_s, cb = _paged_decode_tok_s(model, params, prompts, n_new,
                                          page_size=16, chunk_steps=64)
    speedup = paged_tok_s / base_tok_s

    # telemetry-overhead guard: a fully-enabled registry (metrics + spans +
    # per-request SLO timelines) must not cost more than 3% decode
    # throughput vs the disabled default. Legs are interleaved with the
    # order alternated each round (whichever leg runs first in a pair is
    # systematically faster on a busy host) and min-taken, so scheduler
    # noise and position bias cancel instead of reading as overhead.
    from repro.obs import Telemetry
    run_off, _ = _paged_run_fn(model, params, prompts, n_new,
                               page_size=16, chunk_steps=64)
    run_on, _ = _paged_run_fn(model, params, prompts, n_new,
                              page_size=16, chunk_steps=64,
                              telemetry=Telemetry(enabled=True))
    run_off(), run_on()                          # warm both
    offs, ons = [], []
    for k in range(16):
        if k % 2:
            ons.append(run_on()), offs.append(run_off())
        else:
            offs.append(run_off()), ons.append(run_on())
    dt_off, dt_on = min(offs), min(ons)
    tel_tok_s = (n_new - 1) * B / dt_on
    overhead_pct = max(0.0, (dt_on - dt_off) / dt_off * 100.0)

    # meter-overhead guard: a streaming BankEnergyMeter on the ledger's
    # event funnel (per-event state machine + attribution) must not cost
    # more than 5% decode throughput. Same interleaved min-taken protocol
    # as the telemetry leg. Afterwards the streamed integral is asserted
    # bit-identical to the offline evaluation of the ledger's own trace.
    from repro.core.gating import evaluate
    from repro.obs.energy import BankEnergyMeter
    meter = BankEnergyMeter(1 << 20, 8, policy="conservative")
    run_met, cb_met = _paged_run_fn(model, params, prompts, n_new,
                                    page_size=16, chunk_steps=64,
                                    meter=meter)
    run_met()                                    # warm compile
    mets, offs2 = [], []
    for k in range(16):
        if k % 2:
            mets.append(run_met()), offs2.append(run_off())
        else:
            offs2.append(run_off()), mets.append(run_met())
    dt_off2, dt_met = min(offs2), min(mets)
    met_tok_s = (n_new - 1) * B / dt_met
    meter_overhead_pct = max(0.0, (dt_met - dt_off2) / dt_off2 * 100.0)
    end = float(cb_met.ledger.trace.as_arrays()[0][-1])
    got = meter.finalize(end)
    dur, occ = cb_met.ledger.trace.occupancy_series(end, use="needed")
    ref = evaluate(dur, occ, capacity=meter.capacity, banks=meter.banks,
                   policy=meter.policy, n_reads=0, n_writes=0,
                   char=meter.char)
    assert (got.e_leak, got.e_sw, got.n_transitions) == \
        (ref.e_leak, ref.e_sw, ref.n_transitions), (
        f"streamed meter diverged from offline evaluation: "
        f"{got.e_leak} vs {ref.e_leak}, {got.e_sw} vs {ref.e_sw}")

    report = {
        "config": f"{cfg.name} ({cfg.num_layers} layers)",
        "slots": B,
        "prompt_len": prompt_len,
        "new_tokens": n_new,
        "chunk_steps": 64,
        "page_size": 16,
        "kernel_max_abs_err": err,
        "baseline_tok_s": base_tok_s,
        "paged_tok_s": paged_tok_s,
        "paged_tok_s_telemetry": tel_tok_s,
        "telemetry_overhead_pct": overhead_pct,
        "paged_tok_s_meter": met_tok_s,
        "meter_overhead_pct": meter_overhead_pct,
        "meter_events": meter.n_events,
        "speedup": speedup,
        "pages_peak": cb.stats.peak_pages,
        "note": ("baseline = pre-PR per-token host loop (one decode_step "
                 "dispatch + host-driven sample + sync per token); paged = "
                 "donated lax.scan chunks over the paged cache"),
    }
    assert speedup >= SPEEDUP_BAR, (
        f"paged decode {speedup:.2f}x over per-token loop, bar is "
        f"{SPEEDUP_BAR}x")
    assert overhead_pct <= TELEMETRY_OVERHEAD_BAR_PCT, (
        f"enabled telemetry costs {overhead_pct:.2f}% decode throughput, "
        f"bar is {TELEMETRY_OVERHEAD_BAR_PCT}%")
    assert meter_overhead_pct <= METER_OVERHEAD_BAR_PCT, (
        f"enabled BankEnergyMeter costs {meter_overhead_pct:.2f}% decode "
        f"throughput, bar is {METER_OVERHEAD_BAR_PCT}%")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    return report


def bench_serve_paged():
    """benchmarks.run adapter: (us_per_token, derived) of the paged path."""
    r = bench_serve()
    return 1e6 / r["paged_tok_s"], (
        f"{r['paged_tok_s']:.0f} tok/s vs {r['baseline_tok_s']:.0f} "
        f"baseline ({r['speedup']:.1f}x) err={r['kernel_max_abs_err']:.1e}")


def main() -> None:
    out = sys.argv[1] if len(sys.argv) > 1 else DEFAULT_OUT
    r = bench_serve(out)
    print(json.dumps(r, indent=1))
    print(f"wrote {out}: paged decode {r['paged_tok_s']:.0f} tok/s = "
          f"{r['speedup']:.1f}x over the per-token loop "
          f"({r['baseline_tok_s']:.0f} tok/s)")


if __name__ == "__main__":
    main()
