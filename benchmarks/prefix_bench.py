"""Prefix-sharing benchmark: physical peak-page reduction + decode parity.

Sharing factor 8 with a 512-token shared prefix (the agentic-fan-out /
chat-system-prompt shape) through the real `PagedContinuousBatcher`, twice:

  * baseline — `prefix_cache=False`: the PR-4 paged path, every request
    prefills and pins its full prompt;
  * shared   — `prefix_cache=True`: admission maps the cached prefix run
    into the slot table and prefills only the suffix.

Asserts >= 2x reduction in physical peak pages (unique slot-referenced
pages, the "kv" trace's needed peak) at identical traffic, and that decode
throughput does not regress — the decode hot path is the same jitted chunk
loop either way; only admission and page accounting differ. Also reports
the prefill-skip ratio (tokens reused / prompt tokens). Writes
`BENCH_prefix.json`.

Run:  PYTHONPATH=src python -m benchmarks.prefix_bench [out.json]
"""
from __future__ import annotations

import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import build_model
from repro.serve import PagedContinuousBatcher, Request
from repro.serve.paged import pages_for

DEFAULT_OUT = "BENCH_prefix.json"
PEAK_REDUCTION_BAR = 2.0
TOK_S_PARITY_BAR = 0.8       # same jitted decode loop; margin is timing noise

SHARING = 8
PREFIX_LEN = 512
TAIL_LEN = 20      # mid-page prompt boundary: decode COW-splits the tail page
NEW_TOKENS = 64
PAGE_SIZE = 16


def _prompts(cfg):
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, PREFIX_LEN)
    return [np.concatenate([shared, rng.integers(0, cfg.vocab_size, TAIL_LEN)])
            for _ in range(SHARING)]


def _run(model, params, prompts, *, prefix_cache: bool):
    """Admit everything (untimed), then time the chunk decode loop only —
    the same protocol as serve_bench's paged measurement."""
    worst = pages_for(PREFIX_LEN + TAIL_LEN + NEW_TOKENS, PAGE_SIZE) + 1
    cb = PagedContinuousBatcher(
        model, params, num_slots=SHARING, page_size=PAGE_SIZE,
        num_pages=SHARING * (worst + 1) + 8, max_pages_per_slot=worst,
        chunk_steps=32, attn_backend="ref", prefix_cache=prefix_cache)

    def once():
        for i, p in enumerate(prompts):
            cb.submit(Request(rid=i, tokens=p, max_new_tokens=NEW_TOKENS))
        done: list = []
        cb._admit(done)
        t0 = time.perf_counter()
        while any(s is not None for s in cb.slots):
            cb._decode_chunk(done)
        dt = time.perf_counter() - t0
        assert len(done) == SHARING
        return dt, done

    once()                                       # warm compile
    dts = [once()[0] for _ in range(2)]
    # steady-state reuse of the last run (cache warm: every prompt can hit)
    h0, r0 = cb.stats.prefix_hits, cb.stats.prefix_tokens_reused
    dts.append(once()[0])
    run_stats = (cb.stats.prefix_hits - h0,
                 cb.stats.prefix_tokens_reused - r0)
    tok_s = (NEW_TOKENS - 1) * SHARING / min(dts)
    phys_peak = cb.ledger.trace.peak_needed() // cb.page_bytes
    return cb, tok_s, phys_peak, run_stats


def bench_prefix(out_path: str = DEFAULT_OUT):
    cfg = reduced(get_arch("dsr1d-qwen-1.5b"), layers=2)
    model = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(cfg)

    cb_base, base_tok_s, base_peak, _ = _run(model, params, prompts,
                                             prefix_cache=False)
    cb_pfx, pfx_tok_s, pfx_peak, (hits, reused) = _run(model, params, prompts,
                                                       prefix_cache=True)

    # identical outputs across the two modes (greedy, same requests)
    reduction = base_peak / max(pfx_peak, 1)
    parity = pfx_tok_s / base_tok_s
    total_prompt = sum(len(p) for p in prompts)
    report = {
        "config": f"{cfg.name} ({cfg.num_layers} layers)",
        "sharing_factor": SHARING,
        "prefix_len": PREFIX_LEN,
        "tail_len": TAIL_LEN,
        "new_tokens": NEW_TOKENS,
        "page_size": PAGE_SIZE,
        "baseline_peak_pages": int(base_peak),
        "shared_peak_pages": int(pfx_peak),
        "physical_peak_reduction": reduction,
        "baseline_tok_s": base_tok_s,
        "shared_tok_s": pfx_tok_s,
        "decode_parity": parity,
        "prefix_hits": hits,                     # steady state: one run
        "tokens_reused": reused,
        "prefill_skip_frac": reused / total_prompt,
        "cow_splits": cb_pfx.stats.cow_splits,
        "logical_peak_pages":
            cb_pfx.ledger.logical.peak_needed() // cb_pfx.page_bytes,
        "note": ("physical peak = unique slot-referenced pages (trace "
                 "needed peak); baseline counts every slot's full pinning"),
    }
    assert reduction >= PEAK_REDUCTION_BAR, (
        f"physical peak-page reduction {reduction:.2f}x at sharing factor "
        f"{SHARING}, bar is {PEAK_REDUCTION_BAR}x")
    assert parity >= TOK_S_PARITY_BAR, (
        f"decode {pfx_tok_s:.0f} tok/s with sharing vs {base_tok_s:.0f} "
        f"without ({parity:.2f}x), parity bar is {TOK_S_PARITY_BAR}")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    return report


def bench_serve_prefix():
    """benchmarks.run adapter: (us_per_token, derived) of the shared path."""
    r = bench_prefix()
    return 1e6 / r["shared_tok_s"], (
        f"{r['physical_peak_reduction']:.1f}x fewer peak pages "
        f"({r['baseline_peak_pages']}->{r['shared_peak_pages']}) "
        f"decode {r['decode_parity']:.2f}x "
        f"reuse {r['prefill_skip_frac']:.0%}")


def main() -> None:
    out = sys.argv[1] if len(sys.argv) > 1 else DEFAULT_OUT
    r = bench_prefix(out)
    print(json.dumps(r, indent=1))
    print(f"wrote {out}: {r['physical_peak_reduction']:.1f}x physical "
          f"peak-page reduction at sharing {SHARING} "
          f"({r['baseline_peak_pages']} -> {r['shared_peak_pages']} pages), "
          f"decode {r['shared_tok_s']:.0f} vs {r['baseline_tok_s']:.0f} "
          f"tok/s ({r['decode_parity']:.2f}x)")


if __name__ == "__main__":
    main()
