"""Traffic-campaign benchmarks: trace generation throughput and the
vectorized candidate-grid evaluation path."""
from __future__ import annotations

import numpy as np

from benchmarks.common import timed
from repro.configs import get_arch
from repro.traffic import LengthModel, generate, simulate_traffic
from repro.traffic.campaign import fast_candidate_energies

MIB = 2**20


def bench_traffic_trace():
    """Occupancy-trace construction for one 60 s GQA scenario."""
    cfg = get_arch("dsr1d-qwen-1.5b")
    reqs = generate("poisson", 4.0, 60.0, seed=0,
                    lengths=LengthModel(max_len=1024))

    def run():
        return simulate_traffic(cfg, reqs, num_slots=8, max_len=1024)

    sim, us = timed(run)
    return us, (f"events={len(sim.trace.ev_times)} "
                f"peak={sim.trace.peak_needed()/MIB:.1f}MiB")


def bench_traffic_fast_grid():
    """Jit'd (C x B) candidate grid on a resampled traffic trace — the
    thousand-scenario campaign inner loop."""
    cfg = get_arch("dsr1d-qwen-1.5b")
    reqs = generate("bursty", 4.0, 60.0, seed=0,
                    lengths=LengthModel(max_len=1024))
    sim = simulate_traffic(cfg, reqs, num_slots=8, max_len=1024)
    trace = sim.trace.resampled(0.05, sim.total_time)
    dur, occ = trace.occupancy_series(sim.total_time, use="needed")
    caps = list(range(32, 256 + 1, 16))
    banks = [1, 2, 4, 8, 16, 32]
    kw = dict(capacities_mib=caps, banks=banks, alpha=0.9,
              n_reads=sim.bundle.access.n_reads("kv"),
              n_writes=sim.bundle.access.n_writes("kv"), backend="ref")

    fast_candidate_energies(dur, occ, **kw)       # compile
    out, us = timed(fast_candidate_energies, dur, occ, **kw)
    return us, (f"candidates={len(out)} segs={len(dur)} "
                f"best={np.min(out)*1e3:.1f}mJ")
