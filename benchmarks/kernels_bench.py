"""Kernel microbenches: wall time of the jnp reference path on CPU (the Pallas
kernels are TPU-target; interpret mode is correctness-only) + one interpret
correctness spot check per kernel."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _wall(fn, *args, reps=3):
    fn(*args).block_until_ready() if hasattr(fn(*args), "block_until_ready") \
        else None
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def bench_flash_attention():
    from repro.kernels.flash_attention import (flash_attention,
                                               flash_attention_ref)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, H, K, S, d = 1, 8, 2, 1024, 64
    q = jax.random.normal(ks[0], (B, H, S, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, K, S, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, K, S, d), jnp.float32)
    us = _wall(lambda a, b, c: flash_attention(a, b, c, backend="ref"),
               q, k, v)
    i = flash_attention(q[:, :, :128], k[:, :, :128], v[:, :, :128],
                        backend="interpret")
    r = flash_attention_ref(q[:, :, :128], k[:, :, :128], v[:, :, :128])
    err = float(jnp.max(jnp.abs(i - r)))
    return us, f"S={S} H={H} gqa_group={H//K} interpret_err={err:.1e}"


def bench_gqa_decode():
    from repro.kernels.gqa_decode import gqa_decode, gqa_decode_ref
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, H, K, T, d = 8, 16, 2, 8192, 128
    q = jax.random.normal(ks[0], (B, H, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, K, T, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, K, T, d), jnp.float32)
    lengths = jnp.full((B,), T, jnp.int32)
    us = _wall(lambda a, b, c: gqa_decode(a, b, c, lengths, backend="ref"),
               q, k, v)
    i = gqa_decode(q[:2, :, :], k[:2, :, :512], v[:2, :, :512],
                   jnp.full((2,), 512, jnp.int32), backend="interpret")
    r = gqa_decode_ref(q[:2], k[:2, :, :512], v[:2, :, :512],
                       jnp.full((2,), 512, jnp.int32))
    err = float(jnp.max(jnp.abs(i - r)))
    return us, f"T={T} kv_bytes/group_shared interpret_err={err:.1e}"


def bench_int8_matmul():
    from repro.kernels.int8_matmul import (int8_matmul, quantize_cols,
                                           quantize_rows)
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    M, K, N = 512, 2048, 512
    x = jax.random.normal(ks[0], (M, K))
    w = jax.random.normal(ks[1], (K, N))
    xq, sx = quantize_rows(x)
    wq, sw = quantize_cols(w)
    us = _wall(lambda a, b: int8_matmul(a, b, sx, sw, backend="ref"), xq, wq)
    full = np.asarray(x @ w)
    got = np.asarray(int8_matmul(xq, wq, sx, sw, backend="ref"))
    rel = np.abs(got - full).max() / np.abs(full).max()
    return us, f"{M}x{K}x{N} quant_rel_err={rel:.3f}"


def bench_bank_energy():
    from repro.kernels.bank_energy import bank_activity_stats, candidate_grid
    rng = np.random.default_rng(0)
    S = 1_000_000                     # TPU-scale trace
    d = rng.random(S).astype(np.float32) * 1e-5
    occ = (rng.random(S) * 128 * 2**20).astype(np.float32)
    us_, nb, meta = candidate_grid(
        [c * 2**20 for c in (48, 64, 80, 96, 112, 128)],
        [1, 2, 4, 8, 16, 32], 0.9)
    us = _wall(lambda a, b: bank_activity_stats(a, b, us_, nb, backend="ref"),
               jnp.asarray(d), jnp.asarray(occ))
    return us, f"segments={S} candidates={len(meta)}"
