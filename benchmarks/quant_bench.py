"""Quantized paged-KV benchmark: fp32 vs int8 vs fp8 serving.

Three measurements on the reduced dsr1d config, identical request streams:

  * decode tok/s through the paged chunk loop per kv_dtype (the quantized
    paths add a per-row quantize on append and an in-register dequant in
    the attention reference — parity with fp32 is the bar, not speedup:
    the win is bytes, which Stage I/II convert into gating energy);
  * max-abs logit error of the int8 / fp8 rollouts vs the fp32 batcher
    (greedy tokens must match exactly on this config);
  * bytes/page per kv_dtype via `serve.paged.page_bytes` (int8 carries a
    4-byte f32 scale per (page, kv_head, row); fp8-E4M3 is scale-free).

Also checks the quantized paged kernel (interpret mode) against its jnp
reference on a ragged page-table batch, and the pinned quantization-error
bound vs the fp32 kernel. Writes `BENCH_quant.json`.

Run:  PYTHONPATH=src python -m benchmarks.quant_bench [out.json]
"""
from __future__ import annotations

import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.serve_bench import _paged_run_fn
from repro.configs import get_arch, reduced
from repro.kernels.quant import quantize_page_rows
from repro.models import build_model
from repro.serve import Request
from repro.serve.paged import page_bytes

DEFAULT_OUT = "BENCH_quant.json"
INT8_BYTES_BAR = 2.0       # >=2x smaller pages than fp32 (scales included)
FP8_BYTES_BAR = 4.0        # fp8-E4M3 is scale-free: exactly 4x
TOK_S_PARITY_BAR = 0.9     # quantized decode >= 0.9x fp32 throughput
KERNEL_REF_TOL = 1e-6      # quant kernel vs mirrored jnp reference
QUANT_VS_FP32_BOUND = 0.05  # pinned: quantized attention vs fp32 kernel


def _ragged_case(rng, B=4, H=12, K=2, d=64, ps=16, P=4, N=24):
    q = jnp.asarray(rng.normal(size=(B, H, d)), jnp.float32)
    pk = jnp.asarray(rng.normal(size=(N, K, ps, d)), jnp.float32)
    pv = jnp.asarray(rng.normal(size=(N, K, ps, d)), jnp.float32)
    lengths = np.array([1, 16, 37, 64], np.int32)[:B]
    pt = np.zeros((B, P), np.int64)
    ids = list(range(1, N))
    rng.shuffle(ids)
    for b in range(B):
        for j in range(-(-int(lengths[b]) // ps)):
            pt[b, j] = ids.pop()
    return q, pk, pv, jnp.asarray(pt, jnp.int32), jnp.asarray(lengths)


def _kernel_exactness() -> tuple:
    """(quant kernel vs quant ref, quant ref vs fp32 kernel) max abs err."""
    from repro.kernels.paged_gqa_decode import (
        paged_gqa_decode, paged_gqa_decode_quant,
        paged_gqa_decode_quant_mirror_ref)
    rng = np.random.default_rng(0)
    q, pk, pv, pt, lengths = _ragged_case(rng)
    qk, ks = quantize_page_rows(pk)
    qv, vs = quantize_page_rows(pv)
    out = paged_gqa_decode_quant(q, qk, qv, ks, vs, pt, lengths,
                                 backend="interpret")
    ref = paged_gqa_decode_quant_mirror_ref(q, qk, qv, ks, vs, pt, lengths)
    fp32 = paged_gqa_decode(q, pk, pv, pt, lengths, backend="interpret")
    return float(jnp.abs(out - ref).max()), float(jnp.abs(out - fp32).max())


def _decode_tok_s(model, params, prompts, n_new, kv_dtype) -> float:
    run, _ = _paged_run_fn(model, params, prompts, n_new, page_size=16,
                           chunk_steps=64, kv_dtype=kv_dtype)
    run()                                        # warm compile
    dt = min(run() for _ in range(3))
    return (n_new - 1) * prompts.shape[0] / dt


def _rollout(model, params, prompts, n_new, kv_dtype):
    """{rid: (tokens, logits (T, V))} of a full greedy rollout."""
    _, cb = _paged_run_fn(model, params, prompts, n_new, page_size=16,
                          chunk_steps=16, kv_dtype=kv_dtype,
                          collect_logits=True)
    for i in range(prompts.shape[0]):
        cb.submit(Request(rid=i, tokens=prompts[i], max_new_tokens=n_new))
    done = cb.run()
    return {r.rid: (list(map(int, r.tokens)), np.stack(r.logits))
            for r in done}


def bench_quant(out_path: str = DEFAULT_OUT):
    cfg = reduced(get_arch("dsr1d-qwen-1.5b"), layers=2)
    model = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, prompt_len, n_new = 4, 32, 64
    prompts = rng.integers(0, cfg.vocab_size, (B, prompt_len)).astype(np.int32)

    err_ref, err_fp32 = _kernel_exactness()
    assert err_ref < KERNEL_REF_TOL, (
        f"quant kernel vs reference: max abs err {err_ref:.2e}")
    assert err_fp32 < QUANT_VS_FP32_BOUND, (
        f"quantized vs fp32 kernel: max abs err {err_fp32:.2e}")

    pb = {dt: page_bytes(cfg, 16, *spec) for dt, spec in
          [("fp32", (4, 0)), ("int8", (1, 4)), ("fp8", (1, 0))]}
    int8_ratio = pb["fp32"] / pb["int8"]
    fp8_ratio = pb["fp32"] / pb["fp8"]
    assert int8_ratio >= INT8_BYTES_BAR, f"int8 pages only {int8_ratio:.2f}x"
    assert fp8_ratio >= FP8_BYTES_BAR, f"fp8 pages only {fp8_ratio:.2f}x"

    tok_s = {dt: _decode_tok_s(model, params, prompts, n_new, dt)
             for dt in ("native", "int8", "fp8")}
    roll = {dt: _rollout(model, params, prompts, n_new // 4, dt)
            for dt in ("native", "int8", "fp8")}
    logit_err, tokens_match = {}, {}
    for dt in ("int8", "fp8"):
        logit_err[dt] = max(
            float(np.abs(roll[dt][i][1] - roll["native"][i][1]).max())
            for i in roll["native"])
        tokens_match[dt] = all(roll[dt][i][0] == roll["native"][i][0]
                               for i in roll["native"])

    report = {
        "config": f"{cfg.name} ({cfg.num_layers} layers)",
        "slots": B, "prompt_len": prompt_len, "new_tokens": n_new,
        "page_size": 16, "chunk_steps": 64,
        "kernel_vs_ref_err": err_ref,
        "kernel_vs_fp32_err": err_fp32,
        "page_bytes": pb,
        "int8_bytes_ratio": int8_ratio,
        "fp8_bytes_ratio": fp8_ratio,
        "fp32_tok_s": tok_s["native"],
        "int8_tok_s": tok_s["int8"],
        "fp8_tok_s": tok_s["fp8"],
        "int8_logit_err": logit_err["int8"],
        "fp8_logit_err": logit_err["fp8"],
        "int8_tokens_match_fp32": tokens_match["int8"],
        "fp8_tokens_match_fp32": tokens_match["fp8"],
        "note": ("tok/s through the paged chunk loop (jnp ref attention on "
                 "CPU); the quantized win is bytes/page, throughput parity "
                 "is the guard"),
    }
    for dt in ("int8", "fp8"):
        rel = tok_s[dt] / tok_s["native"]
        assert rel >= TOK_S_PARITY_BAR, (
            f"{dt} decode at {rel:.2f}x fp32 throughput, bar is "
            f"{TOK_S_PARITY_BAR}x")
        assert tokens_match[dt], f"{dt} greedy tokens diverged from fp32"
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    return report


def bench_serve_quant():
    """benchmarks.run adapter: (us_per_token, derived) of the int8 path."""
    r = bench_quant()
    return 1e6 / r["int8_tok_s"], (
        f"int8 {r['int8_tok_s']:.0f} tok/s ({r['int8_tok_s'] / r['fp32_tok_s']:.2f}x fp32), "
        f"pages {r['int8_bytes_ratio']:.2f}x/{r['fp8_bytes_ratio']:.2f}x "
        f"smaller, logit err {r['int8_logit_err']:.1e}")


def main() -> None:
    out = sys.argv[1] if len(sys.argv) > 1 else DEFAULT_OUT
    r = bench_quant(out)
    print(json.dumps(r, indent=1))
    print(f"wrote {out}: int8 {r['int8_tok_s']:.0f} tok/s vs fp32 "
          f"{r['fp32_tok_s']:.0f} tok/s, pages {r['int8_bytes_ratio']:.2f}x "
          f"(int8) / {r['fp8_bytes_ratio']:.2f}x (fp8) smaller")


if __name__ == "__main__":
    main()
