"""Shared Stage-I runs for the benchmark suite (cached per process)."""
from __future__ import annotations

import functools
import time

from repro.configs import get_arch
from repro.core.workload import build_graph
from repro.sim.accelerator import baseline_accelerator, multilevel_accelerator
from repro.sim.engine import simulate

PAPER_M = 2048
PAPER_SUBOPS = 4


@functools.lru_cache(maxsize=None)
def sim_workload(arch: str, sram_mib: int = 128, multilevel: bool = False,
                 m: int = PAPER_M):
    g = build_graph(get_arch(arch), M=m, subops=PAPER_SUBOPS)
    accel = (multilevel_accelerator(sram_mib) if multilevel
             else baseline_accelerator(sram_mib))
    return simulate(g, accel), accel


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6
