"""SLA benchmark: chunked-prefill tail latency + forecast pre-wake gating.

Two guarded measurements, written to `BENCH_sla.json`:

  * serving leg — a long-prompt interleave workload (short streaming
    requests sharing the batcher with 256-token prompts) through the
    `PagedContinuousBatcher` twice: monolithic prefill vs
    `prefill_chunk_tokens`. Both runs must emit bit-identical greedy
    tokens; the chunked run's p99 time-between-tokens (on the logical sim
    clock, the SLO percentiles' time base) must be <= 0.5x the monolithic
    run's — a long admission no longer freezes every active stream for the
    whole prompt.
  * gating leg — the diurnal traffic scenario through the analytic
    occupancy simulator, comparing the reactive timeout controller against
    the PSS-forecast pre-wake controller at the same (C, B): the forecast
    leg must cut wake violations while staying within +2% energy of the
    offline oracle.

Run:  PYTHONPATH=src python -m benchmarks.sla_bench [out.json]
"""
from __future__ import annotations

import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import build_model
from repro.obs import Telemetry
from repro.serve import PagedContinuousBatcher, Request

DEFAULT_OUT = "BENCH_sla.json"
TBT_RATIO_BAR = 0.5                  # chunked p99 TBT vs monolithic
FORECAST_VS_ORACLE_BAR_PCT = 2.0     # forecast energy overhead vs oracle

# long-prompt interleave workload: streaming shorts + fat prompts
SHORTS = 6
LONGS = 4
SHORT_LEN, SHORT_NEW = 8, 64
LONG_LEN, LONG_NEW = 256, 48
PAGE_SIZE = 16
CHUNK_TOKENS = 32


def _requests(cfg):
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(SHORTS):
        reqs.append(Request(rid=i, tokens=rng.integers(
            0, cfg.vocab_size, SHORT_LEN), max_new_tokens=SHORT_NEW))
    for i in range(LONGS):
        reqs.append(Request(rid=SHORTS + i, tokens=rng.integers(
            0, cfg.vocab_size, LONG_LEN), max_new_tokens=LONG_NEW))
    return reqs


def _serve_leg(model, params, chunk_tokens):
    worst = -(-(LONG_LEN + LONG_NEW) // PAGE_SIZE) + 1
    cb = PagedContinuousBatcher(
        model, params, num_slots=4, page_size=PAGE_SIZE,
        num_pages=4 * worst + 8, max_pages_per_slot=worst,
        chunk_steps=8, attn_backend="ref",
        prefill_chunk_tokens=chunk_tokens,
        telemetry=Telemetry(enabled=True))
    for r in _requests(model.cfg):
        cb.submit(r)
    t0 = time.perf_counter()
    done = cb.run()
    wall = time.perf_counter() - t0
    assert len(done) == SHORTS + LONGS
    s = cb.slo_summary()
    toks = {r.rid: list(r.output) for r in done}
    return s, toks, cb, wall


def _gating_leg():
    from repro.traffic import ControllerConfig, LengthModel, generate, \
        simulate_traffic
    from repro.traffic.controller import ForecastConfig, compare
    cfg = get_arch("tinyllama-1.1b")
    reqs = generate("diurnal", 6.0, 30.0, seed=0,
                    lengths=LengthModel(max_len=2048))
    sim = simulate_traffic(cfg, reqs, num_slots=8, max_len=2048)
    dur, occ = sim.trace.occupancy_series(sim.total_time, use="needed")
    c = compare(dur, occ, capacity=32 * 2**20, banks=8,
                n_reads=sim.bundle.access.n_reads("kv"),
                n_writes=sim.bundle.access.n_writes("kv"),
                cfg=ControllerConfig(), fcfg=ForecastConfig(), backend="ref")
    return c


def bench_sla(out_path: str = DEFAULT_OUT):
    cfg = reduced(get_arch("tinyllama-1.1b"), layers=2)
    model = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = model.init(jax.random.PRNGKey(0))

    mono, mono_toks, mono_cb, mono_wall = _serve_leg(model, params, None)
    chnk, chnk_toks, chnk_cb, chnk_wall = _serve_leg(model, params,
                                                     CHUNK_TOKENS)
    assert mono_toks == chnk_toks, \
        "chunked prefill changed the greedy tokens"
    ratio = chnk.tbt_p99_s / mono.tbt_p99_s

    c = _gating_leg()
    f, o = c.forecast, c.online

    report = {
        "config": f"{cfg.name} ({cfg.num_layers} layers)",
        "workload": (f"{SHORTS}x({SHORT_LEN} tok prompt, {SHORT_NEW} new) + "
                     f"{LONGS}x({LONG_LEN} tok prompt, {LONG_NEW} new), "
                     f"4 slots"),
        "prefill_chunk_tokens": CHUNK_TOKENS,
        "mono_tbt_p99_s": mono.tbt_p99_s,
        "chunked_tbt_p99_s": chnk.tbt_p99_s,
        "tbt_p99_ratio": ratio,
        "mono_tbt_p50_s": mono.tbt_p50_s,
        "chunked_tbt_p50_s": chnk.tbt_p50_s,
        "chunked_ttft_p99_s": chnk.ttft_p99_s,
        "mono_ttft_p99_s": mono.ttft_p99_s,
        "prefill_slices": chnk_cb.stats.prefill_slices,
        "tokens_bit_identical": True,
        "forecast_scenario": ("tinyllama-1.1b diurnal@6/s 30s seed=0 "
                              "slots=8 max_len=2048 C=32MiB B=8"),
        "reactive_wake_violations": o.wake_violations,
        "forecast_wake_violations": f.wake_violations,
        "forecast_pre_wakes": f.pre_wakes,
        "forecast_early_wake_s": f.early_wake_s,
        "forecast_vs_oracle_pct": c.forecast_vs_oracle_pct,
        "online_vs_oracle_pct": c.online_vs_oracle_pct,
        "e_oracle_j": c.oracle.e_total,
        "e_reactive_j": o.e_total,
        "e_forecast_j": f.e_total,
        "note": ("TBT percentiles are on the batcher's logical sim clock "
                 "(prefill_tok_s per prompt token, step_time_s per decode "
                 "step), so the guard is deterministic across hosts"),
    }
    assert ratio <= TBT_RATIO_BAR, (
        f"chunked p99 TBT is {ratio:.2f}x monolithic, bar is "
        f"{TBT_RATIO_BAR}x")
    assert f.wake_violations < o.wake_violations, (
        f"forecast controller did not cut wake violations "
        f"({f.wake_violations} vs {o.wake_violations})")
    assert c.forecast_vs_oracle_pct <= FORECAST_VS_ORACLE_BAR_PCT, (
        f"forecast energy {c.forecast_vs_oracle_pct:+.2f}% vs oracle, bar "
        f"is +{FORECAST_VS_ORACLE_BAR_PCT}%")
    with open(out_path, "w") as fh:
        json.dump(report, fh, indent=1)
    return report


def bench_serve_sla():
    """benchmarks.run adapter: (p99-TBT us chunked, derived)."""
    r = bench_sla()
    return r["chunked_tbt_p99_s"] * 1e6, (
        f"p99 TBT {r['tbt_p99_ratio']:.2f}x mono (bar {TBT_RATIO_BAR}) "
        f"wakes {r['forecast_wake_violations']}<"
        f"{r['reactive_wake_violations']} "
        f"fcast {r['forecast_vs_oracle_pct']:+.1f}% vs oracle")


def main() -> None:
    out = sys.argv[1] if len(sys.argv) > 1 else DEFAULT_OUT
    r = bench_sla(out)
    print(json.dumps(r, indent=1))
    print(f"wrote {out}: chunked p99 TBT {r['chunked_tbt_p99_s']*1e3:.2f}ms "
          f"= {r['tbt_p99_ratio']:.2f}x monolithic "
          f"({r['mono_tbt_p99_s']*1e3:.2f}ms); forecast wakes "
          f"{r['forecast_wake_violations']} vs reactive "
          f"{r['reactive_wake_violations']} at "
          f"{r['forecast_vs_oracle_pct']:+.1f}% vs oracle")


if __name__ == "__main__":
    main()
