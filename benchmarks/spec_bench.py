"""Speculative-decoding benchmark: accepted-tokens/s on the paged path.

Two guarded measurements on an 8-layer reduced config, written to
`BENCH_spec.json`:

  * identity leg — the speculative batcher (self-speculation draft = 1 of
    8 layers, k=3) must emit greedy tokens **bit-identical** to the
    non-speculative paged loop on the same requests;
  * throughput leg — decode accepted-tokens/s, speculative vs
    non-speculative, both through warm jitted chunk loops (prefills
    untimed, same `chunk_steps` envelope): one batched `paged_gqa_verify`
    round (k+1 candidate rows through all 8 layers) plus k+1 single-layer
    draft steps replaces up to k+1 sequential full decode steps. The bar
    is >= 1.5x.

The draft here agrees with the target by construction: the benchmark
damps every block's residual branches (attn `wo`, FFN `w_down`) so all
blocks are near-identity and the 1-layer draft tracks the 8-layer
target's argmax. That makes the *acceptance rate* an engineered property
of the weights — it is still measured and reported, never assumed — while
the *speedup at that acceptance* is the real measured quantity: verify
cost, draft cost, rollback cost and host scheduling all run for real.
Random untrained weights have no meaningful agreement to measure.

Also checks the batched verification kernel (interpret mode) against the
jnp reference on a ragged page-table batch.

Run:  PYTHONPATH=src python -m benchmarks.spec_bench [out.json]
"""
from __future__ import annotations

import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import build_model
from repro.models.transformer import self_spec_draft
from repro.serve import PagedContinuousBatcher, Request

DEFAULT_OUT = "BENCH_spec.json"
SPEEDUP_BAR = 1.5

LAYERS = 8
SPEC_K = 3
DAMP = 1e-3
B, PROMPT_LEN, N_NEW = 2, 16, 97
PAGE_SIZE, CHUNK_STEPS = 8, 32


def _build():
    cfg = dataclasses.replace(
        reduced(get_arch("tinyllama-1.1b"), layers=LAYERS),
        d_model=256, d_ff=1024, num_heads=4, num_kv_heads=2, head_dim=64,
        vocab_size=512)
    model = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    # near-identity blocks: the 1-layer draft tracks the 8-layer target
    blocks = []
    for blk in params["blocks"]:
        blk = dict(blk)
        blk["attn"] = dict(blk["attn"], wo=blk["attn"]["wo"] * DAMP)
        blk["ffn"] = dict(blk["ffn"], w_down=blk["ffn"]["w_down"] * DAMP)
        blocks.append(blk)
    params = dict(params, blocks=blocks)
    draft, dparams = self_spec_draft(model, params, skip=LAYERS)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, PROMPT_LEN) for _ in range(B)]
    return cfg, model, params, draft, dparams, prompts


def _batcher(model, params, **kw):
    return PagedContinuousBatcher(
        model, params, num_slots=B, page_size=PAGE_SIZE, num_pages=96,
        max_pages_per_slot=20, chunk_steps=CHUNK_STEPS, attn_backend="ref",
        **kw)


def _run_fn(model, params, prompts, **kw):
    """(timed-run closure, batcher): reuses ONE batcher so its jitted
    chunk loops stay warm across repetitions; prefills are untimed."""
    cb = _batcher(model, params, **kw)

    def run():
        for i, p in enumerate(prompts):
            cb.submit(Request(rid=i, tokens=p, max_new_tokens=N_NEW))
        done: list = []
        cb._admit(done)
        t0 = time.perf_counter()
        while any(s is not None for s in cb.slots):
            cb._decode_chunk(done)
        dt = time.perf_counter() - t0
        assert len(done) == B
        return dt

    return run, cb


def _verify_kernel_exactness() -> float:
    """Max abs error, interpret-mode verify kernel vs jnp reference."""
    from repro.kernels.paged_gqa_verify import (paged_gqa_verify,
                                               paged_gqa_verify_ref)
    rng = np.random.default_rng(0)
    Bk, H, K, d, ps, P, N, V = 4, 12, 2, 64, 16, 6, 24, 4
    q = jnp.asarray(rng.normal(size=(Bk, V, H, d)), jnp.float32)
    pk = jnp.asarray(rng.normal(size=(N, K, ps, d)), jnp.float32)
    pv = jnp.asarray(rng.normal(size=(N, K, ps, d)), jnp.float32)
    lengths = np.array([1, 16, 37, 64], np.int32)
    pt = np.zeros((Bk, P), np.int64)
    ids = list(range(1, N))
    rng.shuffle(ids)
    for b in range(Bk):
        for j in range(-(-(int(lengths[b]) + V) // ps)):
            pt[b, j] = ids.pop()
    pt, lengths = jnp.asarray(pt, jnp.int32), jnp.asarray(lengths)
    out = paged_gqa_verify(q, pk, pv, pt, lengths, backend="interpret")
    ref = paged_gqa_verify_ref(q, pk, pv, pt, lengths)
    return float(jnp.abs(out - ref).max())


def bench_spec(out_path: str = DEFAULT_OUT):
    cfg, model, params, draft, dparams, prompts = _build()

    err = _verify_kernel_exactness()
    assert err < 2e-5, f"verify kernel vs reference: max abs err {err:.2e}"

    # ---- identity leg: full runs, fresh batchers ------------------------
    def full_run(**kw):
        cb = _batcher(model, params, **kw)
        for i, p in enumerate(prompts):
            cb.submit(Request(rid=i, tokens=p, max_new_tokens=N_NEW))
        return {r.rid: list(r.output) for r in cb.run()}, cb

    ref, _ = full_run()
    got, cb_id = full_run(speculate_k=SPEC_K, draft_model=draft,
                          draft_params=dparams)
    assert got == ref, "speculative output diverged from greedy baseline"

    # ---- throughput leg: warm chunk loops, prefills untimed -------------
    run_base, _ = _run_fn(model, params, prompts)
    run_spec, cb_spec = _run_fn(model, params, prompts, speculate_k=SPEC_K,
                                draft_model=draft, draft_params=dparams)
    run_base(), run_spec()                       # warm compile
    dt_base = min(run_base() for _ in range(3))
    dt_spec = min(run_spec() for _ in range(3))
    tok = B * (N_NEW - 1)
    base_tok_s = tok / dt_base
    spec_tok_s = tok / dt_spec
    speedup = spec_tok_s / base_tok_s

    st = cb_spec.stats
    accepted_per_round = st.accepted_tokens / max(st.spec_rounds, 1)
    report = {
        "config": (f"{cfg.name} ({LAYERS} layers, d_model={cfg.d_model}), "
                   f"draft=1 layer self-spec, k={SPEC_K}"),
        "slots": B,
        "prompt_len": PROMPT_LEN,
        "new_tokens": N_NEW,
        "chunk_steps": CHUNK_STEPS,
        "page_size": PAGE_SIZE,
        "residual_damp": DAMP,
        "verify_kernel_max_abs_err": err,
        "bit_identical": got == ref,
        "base_tok_s": base_tok_s,
        "accepted_tok_s": spec_tok_s,
        "speedup": speedup,
        "accepted_per_round": accepted_per_round,
        "acceptance_rate_measured": (
            (st.accepted_tokens - st.spec_rounds)
            / max(st.drafted_tokens, 1)),
        "spec_rounds": st.spec_rounds,
        "rolled_back_pages": cb_id.stats.rolled_back_pages,
        "note": ("acceptance is engineered via near-identity blocks (see "
                 "module docstring) and measured, never assumed; speedup "
                 "compares warm jitted decode chunk loops, prefills "
                 "untimed, greedy tokens bit-identical"),
    }
    assert speedup >= SPEEDUP_BAR, (
        f"speculative decode {speedup:.2f}x accepted-tok/s vs "
        f"non-speculative, bar is {SPEEDUP_BAR}x")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    return report


def bench_serve_spec():
    """benchmarks.run adapter: (us per accepted token, derived)."""
    r = bench_spec()
    return 1e6 / r["accepted_tok_s"], (
        f"{r['speedup']:.2f}x accepted-tok/s (bar {SPEEDUP_BAR}x) "
        f"{r['accepted_per_round']:.2f}/{SPEC_K + 1} tok/round "
        f"bit-identical={r['bit_identical']}")


def main() -> None:
    out = sys.argv[1] if len(sys.argv) > 1 else DEFAULT_OUT
    r = bench_spec(out)
    print(json.dumps(r, indent=1))
    print(f"wrote {out}: {r['accepted_tok_s']:.1f} accepted tok/s = "
          f"{r['speedup']:.2f}x non-speculative ({r['base_tok_s']:.1f}), "
          f"{r['accepted_per_round']:.2f}/{SPEC_K + 1} tok/round, "
          f"{r['rolled_back_pages']} pages rolled back")


if __name__ == "__main__":
    main()
