"""Stage-I throughput: PSS probe-and-tile vs step-by-step DES.

Three measurements, written to `BENCH_stage1.json`:

  * headline — an 8k-context decode horizon on the mini GQA config: PSS
    wall time vs the exact path's cost (estimated from a sample of evenly
    spaced per-step DES runs — actually stepping all 8192 would take
    minutes, which is the point). Asserts the >=50x acceptance bar.
  * full-size dsr1d decode horizon with adaptive refinement (evictions make
    the drop stream piecewise affine): probes used + speedup.
  * micro: DES layer memoization on a full-size decode step, the cached
    `OccupancyTrace.as_arrays` integration on the million-event synthesized
    trace, and the bit-identical traffic fast-forward.

Run:  PYTHONPATH=src python -m benchmarks.stage1_bench [out.json]
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from repro.configs import get_arch, reduced
from repro.core.workload import build_decode_graph
from repro.sim.accelerator import baseline_accelerator
from repro.sim.engine import simulate
from repro.sim.pss import StepProbe, simulate_decode
from repro.traffic.generators import LengthModel, generate
from repro.traffic.occupancy import simulate_traffic

DEFAULT_OUT = "BENCH_stage1.json"
HEADLINE_STEPS = 8192


def _wall(f):
    t0 = time.perf_counter()
    out = f()
    return time.perf_counter() - t0, out


def _estimate_exact(cfg, accel, start_ctx, steps, *, batch, subops,
                    samples=12):
    """Mean per-step DES wall time over evenly spaced contexts x steps."""
    ctxs = np.linspace(start_ctx, start_ctx + steps - 1, samples).astype(int)
    kw = dict(batch=batch, subops=subops, byte=1, policy="fifo",
              memoize_layers=False)
    t0 = time.perf_counter()
    for c in ctxs:
        StepProbe.run(cfg, accel, int(c), **kw)
    per_step = (time.perf_counter() - t0) / samples
    return per_step * steps


def bench_stage1(out_path: str = DEFAULT_OUT) -> dict:
    report = {}

    # --- headline: 8k-context decode, mini GQA config -----------------------
    cfg = reduced(get_arch("dsr1d-qwen-1.5b"), layers=2)
    accel = baseline_accelerator(32)
    kw = dict(start_ctx=1, steps=HEADLINE_STEPS, batch=4, subops=2)
    est_exact = _estimate_exact(cfg, accel, kw["start_ctx"], kw["steps"],
                                batch=kw["batch"], subops=kw["subops"])
    t_pss, res = _wall(lambda: simulate_decode(cfg, accel, fidelity="pss",
                                               **kw))
    n_ev = sum(t.n_events for t in res.traces.values())
    speedup = est_exact / t_pss
    report["headline_8k_decode"] = {
        "config": "dsr1d-qwen-1.5b (reduced, 2 layers)",
        "steps": kw["steps"],
        "probes": len(res.probes),
        "events": n_ev,
        "exact_est_s": est_exact,
        "pss_s": t_pss,
        "speedup": speedup,
        "note": "exact cost estimated from 12 evenly spaced per-step DES "
                "runs x steps",
    }
    assert res.fidelity == "pss"
    assert speedup >= 50, f"PSS speedup {speedup:.1f}x < 50x acceptance bar"

    # cached integration on the synthesized million-event trace
    tr = res.traces["sram"]
    tr._cache = None
    t_cold, _ = _wall(lambda: tr.peak_needed())
    t_warm, _ = _wall(lambda: tr.peak_total())       # served from cache
    report["trace_integration"] = {
        "events": tr.n_events,
        "integrate_cold_s": t_cold,
        "cached_query_s": t_warm,
        "events_per_sec_cold": tr.n_events / max(t_cold, 1e-12),
    }

    # --- full-size dsr1d horizon (adaptive refinement) -----------------------
    cfg_full = get_arch("dsr1d-qwen-1.5b")
    accel_full = baseline_accelerator(128)
    kwf = dict(start_ctx=2048, steps=1024, batch=8, subops=2)
    est_full = _estimate_exact(cfg_full, accel_full, kwf["start_ctx"],
                               kwf["steps"], batch=kwf["batch"],
                               subops=kwf["subops"], samples=6)
    t_full, res_full = _wall(
        lambda: simulate_decode(cfg_full, accel_full, fidelity="pss", **kwf))
    report["full_dsr1d_decode"] = {
        "steps": kwf["steps"],
        "probes": len(res_full.probes),
        "events": sum(t.n_events for t in res_full.traces.values()),
        "exact_est_s": est_full,
        "pss_s": t_full,
        "speedup": est_full / t_full,
    }

    # --- micro: layer memoization (pays off when per-layer DES work is
    # heavy relative to the boundary guards: multilevel full prefill) --------
    from repro.core.workload import build_graph
    from repro.sim.accelerator import multilevel_accelerator
    g = build_graph(cfg_full, M=2048, subops=4)
    ml = multilevel_accelerator(64)
    t_plain, _ = _wall(lambda: simulate(g, ml))
    t_memo, r_memo = _wall(lambda: simulate(g, ml, memoize_layers=True))
    report["layer_memoization"] = {
        "workload": "dsr1d multilevel prefill M=2048",
        "replayed_layers": r_memo.replayed_layers,
        "plain_s": t_plain,
        "memoized_s": t_memo,
        "speedup": t_plain / t_memo,
    }

    # --- micro: traffic fast-forward ----------------------------------------
    reqs = generate("bursty", 6.0, 60.0, seed=0,
                    lengths=LengthModel(max_len=1024))
    t_ex, _ = _wall(lambda: simulate_traffic(cfg_full, reqs, num_slots=8,
                                             max_len=1024,
                                             fidelity="exact"))
    t_ff, _ = _wall(lambda: simulate_traffic(cfg_full, reqs, num_slots=8,
                                             max_len=1024, fidelity="pss"))
    report["traffic_fast_forward"] = {
        "exact_s": t_ex,
        "pss_s": t_ff,
        "speedup": t_ex / t_ff,
    }

    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    return report


def bench_stage1_pss():
    """benchmarks.run adapter: (us_per_call, derived) of the headline run."""
    r = bench_stage1()
    h = r["headline_8k_decode"]
    return h["pss_s"] * 1e6, (
        f"steps={h['steps']} probes={h['probes']} events={h['events']} "
        f"speedup={h['speedup']:.0f}x "
        f"full={r['full_dsr1d_decode']['speedup']:.0f}x")


def main() -> None:
    out = sys.argv[1] if len(sys.argv) > 1 else DEFAULT_OUT
    r = bench_stage1(out)
    print(json.dumps(r, indent=1))
    h = r["headline_8k_decode"]
    print(f"wrote {out}: 8k decode {h['speedup']:.0f}x "
          f"({h['probes']} probes / {h['steps']} steps, "
          f"{h['events']} events), full-config "
          f"{r['full_dsr1d_decode']['speedup']:.0f}x, memoization "
          f"{r['layer_memoization']['speedup']:.2f}x, traffic FF "
          f"{r['traffic_fast_forward']['speedup']:.1f}x")


if __name__ == "__main__":
    main()
