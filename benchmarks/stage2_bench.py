"""Stage-II evaluation throughput: batched engine vs the legacy loop.

Evaluates an identical (C x B x alpha x policy) candidate grid against one
traffic-generated occupancy trace twice — per-candidate scalar
`gating.evaluate`/`evaluate_drowsy` loops vs one batched
`evaluate_candidates` call — verifies they agree to 1e-6 relative, and
writes `BENCH_stage2.json` (candidates/sec both ways, speedup, prune-phase
timing) to start the Stage-II perf trajectory.

Run:  PYTHONPATH=src python -m benchmarks.stage2_bench [out.json]
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from repro.core.candidates import Candidate, evaluate_candidates
from repro.core.gating import Policy, evaluate
from repro.core.sensitivity import evaluate_drowsy
from repro.traffic import LengthModel, generate, simulate_traffic
from repro.configs import get_arch

MIB = 2**20
DEFAULT_OUT = "BENCH_stage2.json"


def _trace(horizon_s: float = 60.0, resample_dt: float = 0.004):
    cfg = get_arch("dsr1d-qwen-1.5b")
    reqs = generate("bursty", 6.0, horizon_s, seed=0,
                    lengths=LengthModel(max_len=1024))
    sim = simulate_traffic(cfg, reqs, num_slots=8, max_len=1024)
    trace = sim.trace.resampled(resample_dt, sim.total_time)
    dur, occ = trace.occupancy_series(sim.total_time, use="needed")
    return (dur, occ, sim.bundle.access.n_reads("kv"),
            sim.bundle.access.n_writes("kv"))


def _grid(peak_mib: int):
    lo = max(16, peak_mib)
    caps = [lo + 16 * k for k in range(6)]
    cands = []
    for c in caps:
        for b in (1, 2, 4, 8, 16, 32):
            for alpha in (0.85, 0.9, 0.95, 1.0):
                for mgm in (1.0, 5.0):
                    cands.append(Candidate(c * MIB, b, alpha, "gate", mgm))
            for mgm in (1.0, 1e3):
                cands.append(Candidate(c * MIB, b, 0.9, "drowsy", mgm))
    return cands


def _best_of(f, repeats: int = 3) -> float:
    """Min wall time over repeats — standard noise control for short runs."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        f()
        best = min(best, time.perf_counter() - t0)
    return best


def _legacy(dur, occ, cands, n_r, n_w) -> np.ndarray:
    out = np.zeros(len(cands))
    for i, c in enumerate(cands):
        if c.policy == "drowsy":
            out[i] = evaluate_drowsy(
                dur, occ, capacity=c.capacity, banks=c.banks, alpha=c.alpha,
                n_reads=n_r, n_writes=n_w,
                off_multiple=c.min_gate_multiple).e_total
        else:
            pol = Policy("g", c.alpha, c.policy == "gate",
                         c.min_gate_multiple)
            out[i] = evaluate(dur, occ, capacity=c.capacity, banks=c.banks,
                              policy=pol, n_reads=n_r, n_writes=n_w).e_total
    return out


def bench_stage2(out_path: str = DEFAULT_OUT):
    dur, occ, n_r, n_w = _trace()
    cands = _grid(int(np.ceil(occ.max() / MIB)))
    kw = dict(n_reads=n_r, n_writes=n_w)

    legacy = _legacy(dur, occ, cands, n_r, n_w)
    t_legacy = _best_of(lambda: _legacy(dur, occ, cands, n_r, n_w))

    res = evaluate_candidates(dur, occ, cands, **kw)      # also warms caches
    t_batched = _best_of(lambda: evaluate_candidates(dur, occ, cands, **kw))

    rel = np.abs(res.e_total - legacy) / np.maximum(np.abs(legacy), 1e-30)
    assert rel.max() < 1e-6, f"batched != legacy (max rel {rel.max():.2e})"

    pruned = evaluate_candidates(dur, occ, cands, prune=True, **kw)
    t_prune = _best_of(
        lambda: evaluate_candidates(dur, occ, cands, prune=True, **kw))
    assert pruned.argmin() == res.argmin()

    report = {
        "segments": int(len(dur)),
        "candidates": len(cands),
        "max_rel_err": float(rel.max()),
        "legacy_s": t_legacy,
        "batched_s": t_batched,
        "prune_then_exact_s": t_prune,
        "speedup": t_legacy / t_batched,
        "prune_speedup": t_legacy / t_prune,
        "legacy_candidates_per_sec": len(cands) / t_legacy,
        "batched_candidates_per_sec": len(cands) / t_batched,
        "pruned_out": int((~pruned.evaluated).sum()),
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    return report


def bench_stage2_engine():
    """benchmarks.run adapter: (us_per_call, derived) of the batched call."""
    r = bench_stage2()
    return r["batched_s"] * 1e6, (
        f"candidates={r['candidates']} segs={r['segments']} "
        f"speedup={r['speedup']:.1f}x prune={r['prune_speedup']:.1f}x")


def main() -> None:
    out = sys.argv[1] if len(sys.argv) > 1 else DEFAULT_OUT
    r = bench_stage2(out)
    print(json.dumps(r, indent=1))
    print(f"wrote {out}: {r['candidates']} candidates x {r['segments']} "
          f"segments, batched {r['speedup']:.1f}x over legacy "
          f"({r['batched_candidates_per_sec']:.0f} cand/s), "
          f"prune-then-exact {r['prune_speedup']:.1f}x")


if __name__ == "__main__":
    main()
