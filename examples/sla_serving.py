"""SLA-aware serving walkthrough: chunked prefill, priority preemption, and
forecast-driven bank pre-wake — the three layers of the serving SLA story.

  1. chunked prefill — a long prompt admits in page-aligned slices with
     decode chunks interleaved, so active streams keep their token cadence:
     p99 time-between-tokens collapses while greedy tokens stay
     bit-identical to the monolithic prefill;
  2. priority preemption — a high-priority arrival evicts the lowest-
     priority slot (pages freed through the retire path, the victim
     requeued for an exact from-scratch replay) instead of queueing;
  3. forecast pre-wake — the PSS-style affine extrapolator pointed at the
     occupancy series wakes SRAM banks just before demand returns, cutting
     wake-latency violations at bounded extra leakage vs the offline
     oracle.

Run:  PYTHONPATH=src python examples/sla_serving.py [--arch tinyllama-1.1b]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import build_model
from repro.obs import Telemetry
from repro.serve import PagedContinuousBatcher, Request
from repro.traffic import ControllerConfig, LengthModel, generate, \
    simulate_traffic
from repro.traffic.controller import ForecastConfig, compare


def _interleave(model, params, chunk_tokens, *, slots, new_tokens):
    cfg = model.cfg
    rng = np.random.default_rng(0)
    cb = PagedContinuousBatcher(
        model, params, num_slots=slots, page_size=16, num_pages=64,
        max_pages_per_slot=12, chunk_steps=8, attn_backend="ref",
        prefill_chunk_tokens=chunk_tokens, telemetry=Telemetry(enabled=True))
    for i in range(3):                              # streaming shorts
        cb.submit(Request(rid=i, tokens=rng.integers(0, cfg.vocab_size, 8),
                          max_new_tokens=new_tokens))
    for i in range(2):                              # fat prompts
        cb.submit(Request(rid=3 + i,
                          tokens=rng.integers(0, cfg.vocab_size, 128),
                          max_new_tokens=8))
    done = cb.run()
    return cb.slo_summary(), {r.rid: list(r.output) for r in done}, cb


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--chunk-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch))
    model = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = model.init(jax.random.PRNGKey(0))

    # ---- 1. chunked prefill under long-prompt interleave -----------------
    mono, mono_toks, _ = _interleave(model, params, None,
                                     slots=args.slots,
                                     new_tokens=args.new_tokens)
    chnk, chnk_toks, ccb = _interleave(model, params, args.chunk_tokens,
                                       slots=args.slots,
                                       new_tokens=args.new_tokens)
    same = mono_toks == chnk_toks
    print(f"sla-serve arch={cfg.name} slots={args.slots} "
          f"chunk={args.chunk_tokens} tok")
    print(f"chunked prefill: {ccb.stats.prefill_slices} slices, tokens "
          f"bit-identical to monolithic: {same}")
    print(f"  p99 TBT  mono={mono.tbt_p99_s*1e3:.2f}ms  "
          f"chunked={chnk.tbt_p99_s*1e3:.2f}ms  "
          f"({chnk.tbt_p99_s / mono.tbt_p99_s:.2f}x)")
    assert same

    # ---- 2. priority preemption ------------------------------------------
    rng = np.random.default_rng(1)
    cb = PagedContinuousBatcher(model, params, num_slots=1, page_size=8,
                                num_pages=32, max_pages_per_slot=8,
                                chunk_steps=2, attn_backend="ref")
    cb.submit(Request(rid=0, tokens=rng.integers(0, cfg.vocab_size, 10),
                      max_new_tokens=20, priority=0))
    started: list = []
    cb._admit(started)
    cb._decode_chunk(started)                     # rid=0 is mid-decode...
    cb.submit(Request(rid=1, tokens=rng.integers(0, cfg.vocab_size, 10),
                      max_new_tokens=8, priority=1))
    done = started + cb.run()                     # ...and gets preempted
    order = [r.rid for r in done]
    victim = next(r for r in done if r.rid == 0)
    print(f"\npriority preemption: finish order {order}, "
          f"rid=0 preempted {victim.preemptions}x and replayed "
          f"({len(victim.output)} tokens, exact restart)")

    # ---- 3. forecast pre-wake on diurnal traffic -------------------------
    reqs = generate("diurnal", 6.0, 20.0, seed=0,
                    lengths=LengthModel(max_len=2048))
    sim = simulate_traffic(get_arch("tinyllama-1.1b"), reqs, num_slots=8,
                           max_len=2048)
    dur, occ = sim.trace.occupancy_series(sim.total_time, use="needed")
    c = compare(dur, occ, capacity=32 * 2**20, banks=8,
                n_reads=sim.bundle.access.n_reads("kv"),
                n_writes=sim.bundle.access.n_writes("kv"),
                cfg=ControllerConfig(), fcfg=ForecastConfig(), backend="ref")
    print("\nforecast pre-wake vs reactive vs oracle "
          "(diurnal@6/s, C=32MiB, B=8):")
    print("  " + c.format().replace("\n", "\n  "))
    f = c.forecast
    print(f"  -> {c.online.wake_violations - f.wake_violations} violations "
          f"avoided for {f.early_wake_s*1e3:.1f}ms early-wake leakage "
          f"({c.forecast_vs_oracle_pct:+.1f}% energy vs oracle)")


if __name__ == "__main__":
    main()
