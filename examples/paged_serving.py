"""Paged KV-cache serving walkthrough: continuous batching over a paged
cache, ending in a Stage-II banking/power-gating sweep over the emitted
page-granular occupancy trace.

The pipeline this demonstrates end to end:

  1. requests with ragged prompts stream through `PagedContinuousBatcher` —
     admission maps each prompt's pages into the slot's page table, decode
     runs in device-resident `lax.scan` chunks with exact per-slot
     positions;
  2. every page alloc/free lands on the batcher's `OccupancyTrace`, so the
     serving run *is* a Stage-I artifact whose occupancy steps in units of
     `page_bytes` (fragmentation and page residency, time-resolved);
  3. `core.explorer.sweep` consumes that `TraceBundle` unchanged and ranks
     (capacity, banks) candidates for the KV SRAM — the paper's Stage II,
     driven by live page-granular serving data.

Run:  PYTHONPATH=src python examples/paged_serving.py [--arch tinyllama-1.1b]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.core.explorer import sweep
from repro.models import build_model
from repro.serve import PagedContinuousBatcher, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--chunk-steps", type=int, default=8)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch))
    model = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = model.init(jax.random.PRNGKey(0))

    cb = PagedContinuousBatcher(
        model, params, num_slots=args.slots, page_size=args.page_size,
        num_pages=64, chunk_steps=args.chunk_steps, attn_backend="ref")
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        cb.submit(Request(rid=i,
                          tokens=rng.integers(0, cfg.vocab_size, 5 + 4 * i),
                          max_new_tokens=args.new_tokens))
    done = cb.run()

    st = cb.stats
    print(f"arch={cfg.name} slots={args.slots} page_size={args.page_size} "
          f"page_bytes={cb.page_bytes}")
    print(f"finished {st.finished}/{st.admitted} requests in {st.chunks} "
          f"chunks ({st.decode_steps} decode steps, {st.prefills} prefills)")
    print(f"pages: {st.pages_allocated} allocated / {st.pages_freed} freed, "
          f"peak {st.peak_pages} resident "
          f"({st.peak_pages * cb.page_bytes} bytes)")
    for r in done[:3]:
        print(f"  rid={r.rid} prompt={len(r.tokens)} -> {r.output[:6]}...")

    # ---- Stage II over the page-granular serving trace -------------------
    bundle = cb.occupancy_bundle()
    tr = bundle.traces["kv"]
    print(f"\ntrace: {tr.n_events} page alloc/free events, "
          f"peak {tr.peak_needed()} B "
          f"({tr.peak_needed() // cb.page_bytes} pages), "
          f"drained to {int(tr.as_arrays()[1][-1])} B")
    table = sweep(bundle, mem_name="kv", capacities_mib=[16, 32],
                  banks=[1, 2, 4, 8])
    print()
    print(table.format())
    best = table.best()
    print(f"\nbest: C={best.capacity_mib} MiB B={best.banks} "
          f"-> {best.result.e_total * 1e3:.2f} mJ")


if __name__ == "__main__":
    main()
