"""Observability walkthrough: telemetry-enabled prefix-sharing serve ->
registry report -> serving SLO percentiles -> Perfetto timeline on disk.

The pipeline this demonstrates end to end:

  1. a `chat_sysprompt` workload is drawn from the seeded traffic
     generators and served by `PagedContinuousBatcher(prefix_cache=True)`
     with an enabled `Telemetry` registry — every admission, prefill,
     decode chunk, COW split and retirement lands in counters, gauges,
     histograms and spans on the batcher's logical sim clock;
  2. the registry prints as a flat metrics report, and per-request
     TTFT / time-between-tokens / e2e latencies come back as p50/p90/p99
     through `cb.slo_summary()`;
  3. `export_chrome_trace` writes the spans plus the Stage-I KV-occupancy
     traces (physical AND logical, when sharing is on) as one
     Chrome-trace-event JSON — drop it on https://ui.perfetto.dev or
     chrome://tracing and scrub the very timeline Stage II prices.

Run:  PYTHONPATH=src python examples/obs_timeline.py [--arch tinyllama-1.1b]
"""
import argparse
from dataclasses import asdict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import build_model
from repro.obs import Telemetry, export_chrome_trace
from repro.serve import PagedContinuousBatcher, Request
from repro.traffic.generators import (LengthModel, generate_workload,
                                      materialize_tokens)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--prefix-len", type=int, default=48)
    ap.add_argument("--sharing", type=int, default=4)
    ap.add_argument("--out", default="obs_timeline.json")
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch))
    model = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = model.init(jax.random.PRNGKey(0))

    # ---- telemetry-enabled serve ----------------------------------------
    lengths = LengthModel(prompt_mean=16.0, prompt_sigma=0.4,
                          output_mean=args.new_tokens, max_len=96)
    specs = generate_workload("chat_sysprompt", rate=4.0,
                              horizon_s=float(args.requests), seed=0,
                              lengths=lengths, prefix_len=args.prefix_len,
                              sharing=args.sharing)[:args.requests]
    tokens = materialize_tokens(specs, cfg.vocab_size, seed=0)

    tel = Telemetry(enabled=True)
    cb = PagedContinuousBatcher(
        model, params, num_slots=args.slots, page_size=args.page_size,
        num_pages=128, chunk_steps=8, attn_backend="ref", prefix_cache=True,
        telemetry=tel)
    for s, toks in zip(specs, tokens):
        cb.submit(Request(rid=s.rid, tokens=np.asarray(toks),
                          max_new_tokens=max(s.output_len, 2)))
    done = cb.run()
    print(f"served {len(done)} requests on {args.slots} slots "
          f"({cb.stats.chunks} chunks, {cb.stats.decode_steps} decode steps,"
          f" {cb.stats.prefix_hits} prefix hits)")

    # ---- registry report + SLO percentiles ------------------------------
    print()
    print(tel.format())
    summary = cb.slo_summary()
    print()
    print(summary.format())

    # ---- Perfetto timeline ----------------------------------------------
    bundle = cb.occupancy_bundle()
    export_chrome_trace(args.out, tel, traces=bundle.traces.values(),
                        end_time=bundle.total_time,
                        other_data={"slo": asdict(summary)})
    print(f"\nwrote {args.out} ({len(tel.spans)} spans, "
          f"{len(bundle.traces)} counter tracks) — load it at "
          f"ui.perfetto.dev or chrome://tracing: request lanes under "
          f"'requests', slot lanes + KV occupancy under 'serving'")


if __name__ == "__main__":
    main()
