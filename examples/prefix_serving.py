"""Prefix-sharing serving walkthrough: shared-prefix traffic -> radix-index
prefill skip -> dual logical/physical occupancy traces -> Stage-II sweep
showing the extra power-gating savings sharing unlocks.

The pipeline this demonstrates end to end:

  1. a `chat_sysprompt` workload (tenants share system prompts) is drawn
     from the seeded traffic generators and materialized into token
     streams whose leading tokens actually coincide;
  2. `PagedContinuousBatcher(prefix_cache=True)` admits them: the radix
     prefix index maps cached pages straight into each slot's page table
     (only the suffix is prefilled — bit-exact vs a full prefill), the
     last page of a shared run is COW-split on the first divergent write,
     and unreferenced cached prefixes are LRU-evicted under pressure;
  3. the ledger emits two Stage-I traces: "kv_logical" (what every slot
     *demands*) and "kv" (unique *physical* pages actually resident —
     always <=);
  4. `core.explorer.sweep` prices (capacity, banks) against both: the
     energy gap at the best configuration is the gating headroom that
     prefix sharing unlocked.

Run:  PYTHONPATH=src python examples/prefix_serving.py [--arch tinyllama-1.1b]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.core.explorer import sweep
from repro.models import build_model
from repro.serve import PagedContinuousBatcher, Request
from repro.traffic.generators import (LengthModel, generate_workload,
                                      materialize_tokens)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--prefix-len", type=int, default=48)
    ap.add_argument("--sharing", type=int, default=4)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch))
    model = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = model.init(jax.random.PRNGKey(0))

    # ---- shared-prefix traffic ------------------------------------------
    lengths = LengthModel(prompt_mean=16.0, prompt_sigma=0.4,
                          output_mean=args.new_tokens, max_len=96)
    specs = generate_workload("chat_sysprompt", rate=4.0,
                              horizon_s=args.requests / 4.0, seed=0,
                              lengths=lengths, prefix_len=args.prefix_len,
                              sharing=args.sharing)[:args.requests]
    tokens = materialize_tokens(specs, cfg.vocab_size, seed=0)
    print(f"workload: {len(specs)} requests, "
          f"{len({s.prefix_id for s in specs})} tenants, "
          f"prefix~{args.prefix_len} tok, sharing~{args.sharing}")

    cb = PagedContinuousBatcher(
        model, params, num_slots=args.slots, page_size=args.page_size,
        num_pages=128, chunk_steps=8, attn_backend="ref", prefix_cache=True)
    for s, toks in zip(specs, tokens):
        cb.submit(Request(rid=s.rid, tokens=np.asarray(toks),
                          max_new_tokens=max(s.output_len, 2)))
    done = cb.run()

    st = cb.stats
    total_prompt = sum(s.prompt_len for s in specs)
    print(f"finished {st.finished}/{st.admitted}: prefix hits "
          f"{st.prefix_hits}, {st.prefix_tokens_reused}/{total_prompt} "
          f"prompt tokens reused ({st.prefix_tokens_reused / total_prompt:.0%}"
          f" prefill skipped), {st.cow_splits} COW splits, "
          f"{st.evicted_pages} pages evicted")
    for r in done[:3]:
        print(f"  rid={r.rid} prompt={len(r.tokens)} -> {r.output[:5]}...")

    # ---- dual occupancy traces ------------------------------------------
    bundle = cb.occupancy_bundle()
    phys = bundle.traces["kv"]
    logi = bundle.traces["kv_logical"]
    pb = cb.page_bytes
    print(f"\noccupancy: logical peak {logi.peak_needed() // pb} pages, "
          f"physical peak {phys.peak_needed() // pb} pages "
          f"({logi.peak_needed() / max(phys.peak_needed(), 1):.2f}x lower), "
          f"cache-resident peak {phys.peak_total() // pb} pages")

    # ---- Stage II on both views -----------------------------------------
    t_phys = sweep(bundle, mem_name="kv", capacities_mib=[16],
                   banks=[1, 2, 4, 8])
    print("\n# Stage II vs PHYSICAL occupancy (what sharing actually pins)")
    print(t_phys.format())

    # gating headroom at the design point a NON-sharing allocator needs:
    # capacity sized to the logical peak, gated by what actually resides
    from repro.core.candidates import evaluate_candidates, make_grid
    cap = max(logi.peak_needed(), pb)
    cands = make_grid([cap], [8], alphas=(1.0,))
    n_r = bundle.access.n_reads("kv")
    n_w = bundle.access.n_writes("kv")
    out = []
    for tr in (logi, phys):
        dur, occ = tr.occupancy_series(bundle.total_time, use="needed")
        out.append(evaluate_candidates(dur, occ, cands, n_reads=n_r,
                                       n_writes=n_w).e_total[0])
    e_logical, e_physical = out
    print(f"\ngating the logical-peak-sized KV SRAM (C={cap} B, B=8):")
    print(f"  against logical demand : {e_logical * 1e3:.3f} mJ")
    print(f"  against physical pages : {e_physical * 1e3:.3f} mJ")
    print(f"extra power-gating savings unlocked by prefix sharing: "
          f"{(1 - e_physical / e_logical) * 100:.1f}%")


if __name__ == "__main__":
    main()
