"""Quickstart: the TRAPTI two-stage flow in ~40 lines.

Stage I  — cycle-level simulation of DeepSeek-R1-Distill-Qwen-1.5B (GQA) and
           GPT-2 XL (MHA) on the paper's accelerator (4x 128x128 SAs, shared
           SRAM), extracting time-resolved occupancy traces.
Stage II — offline banking + power-gating exploration on those traces.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import get_arch
from repro.core.explorer import min_capacity_mib, sweep
from repro.core.workload import build_graph
from repro.sim.accelerator import baseline_accelerator
from repro.sim.engine import simulate

MIB = 2**20


def main() -> None:
    for name, cap in (("dsr1d-qwen-1.5b", 128), ("gpt2-xl", 160)):
        cfg = get_arch(name)
        graph = build_graph(cfg, M=2048, subops=4)
        print(f"\n=== {name}: {graph.total_macs()/1e12:.2f} TMACs, "
              f"{len(graph.ops)} ops ===")

        # Stage I
        sim = simulate(graph, baseline_accelerator(cap))
        trace = sim.traces["sram"]
        print(f"simulated {sim.total_time*1e3:.1f} ms | "
              f"peak needed {trace.peak_needed()/MIB:.1f} MiB | "
              f"PE util {sim.pe_utilization*100:.1f}% | "
              f"capacity write-backs: {sim.writebacks}")

        # Stage II
        lo = min_capacity_mib(trace.peak_needed())
        table = sweep(sim, capacities_mib=[lo, 128])
        print(table.format())
        best = table.best()
        print(f"--> recommended: C={best.capacity_mib} MiB, B={best.banks} "
              f"banks ({best.delta_e_pct:+.1f}% energy, "
              f"{best.delta_a_pct:+.1f}% area vs monolithic)")


if __name__ == "__main__":
    main()
