"""End-to-end training driver: train a ~100M-param GQA LM for a few hundred
steps on CPU with the full production substrate — deterministic data pipeline,
AdamW + cosine schedule, async atomic checkpointing, straggler monitor, and a
mid-run preemption + recovery to prove fault tolerance.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import shutil
from dataclasses import replace

import jax.numpy as jnp

from repro.configs import get_arch
from repro.data import DataConfig, SyntheticTokens
from repro.models import build_model
from repro.optim import AdamW, cosine_with_warmup
from repro.train import LoopConfig, TrainLoop


def small_lm():
    """~100M-param tinyllama-family config that trains on CPU."""
    base = get_arch("tinyllama-1.1b")
    return replace(base, name="tinyllama-100m", num_layers=4, d_model=512,
                   num_heads=8, num_kv_heads=2, head_dim=64, d_ff=1536,
                   vocab_size=32000, max_seq_len=1024)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = small_lm()
    model = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    n_params = cfg.param_count()
    print(f"model: {cfg.name}  ~{n_params/1e6:.0f}M params")

    opt = AdamW(lr=cosine_with_warmup(1e-3, args.steps // 10, args.steps),
                weight_decay=0.01)
    data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=args.seq,
                                      global_batch=args.batch, seed=42))
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    loop_cfg = LoopConfig(total_steps=args.steps, ckpt_every=50,
                          ckpt_dir=args.ckpt_dir, log_every=25)

    # ---- phase 1: run until an injected preemption at 60% ------------------
    fail_at = int(args.steps * 0.6)
    print(f"phase 1: training with injected preemption at step {fail_at}")
    try:
        TrainLoop(model, opt, data, loop_cfg, fail_at_step=fail_at).run()
    except RuntimeError as e:
        print(f"  !! {e} — restarting from the latest checkpoint")

    # ---- phase 2: restart; the loop resumes from the checkpoint -------------
    loop = TrainLoop(model, opt, data, loop_cfg)
    out = loop.run()
    hist = out["history"]
    print(f"phase 2: resumed at step {hist[0]['step']}")
    for h in hist[::25] + [hist[-1]]:
        flag = " STRAGGLER" if h["straggler"] else ""
        print(f"  step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"{h['time_s']*1e3:6.1f} ms{flag}")
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'OK: decreasing' if last < first else 'WARN: not decreasing'})")


if __name__ == "__main__":
    main()
