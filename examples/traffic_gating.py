"""Serving traffic -> occupancy trace -> online power gating, end to end.

The paper's Stage I traces ONE inference; here the workload is a stream of
requests (Poisson arrivals, lognormal lengths) served by a continuous
batcher, so KV occupancy fluctuates with load — the regime where the online
power-gating controller earns its keep:

  1. generate one seeded request stream;
  2. replay it through the analytic multi-tenant occupancy model for the
     paper's MHA (GPT-2 XL) and GQA (DeepSeek-R1-Distill-Qwen-1.5B) workloads;
  3. compare no-gating vs offline-oracle vs online-controller energy on each
     trace, plus the wake-up latency the online policy exposes;
  4. feed the same traffic trace to the unmodified Stage-II sweep().

Run:  PYTHONPATH=src python examples/traffic_gating.py
"""
from repro.configs import get_arch
from repro.core.explorer import min_capacity_mib, sweep
from repro.traffic import LengthModel, compare, generate, simulate_traffic
from repro.traffic.occupancy import utilization_summary

MIB = 2**20


def main() -> None:
    # one stream, both architectures: identical traffic, directly comparable
    reqs = generate("poisson", 4.0, 20.0, seed=0,
                    lengths=LengthModel(max_len=1024))
    print(f"traffic: {len(reqs)} requests over 20 s (poisson @ 4/s, seed 0)")

    for name in ("gpt2-xl", "dsr1d-qwen-1.5b"):
        cfg = get_arch(name)
        sim = simulate_traffic(cfg, reqs, num_slots=8, max_len=1024)
        u = utilization_summary(sim)
        print(f"\n=== {name} ===")
        print(f"peak {u['peak_bytes']/MIB:.1f} MiB | "
              f"mean {u['mean_bytes']/MIB:.1f} MiB | "
              f"p95 latency {u['p95_latency_s']:.2f} s | "
              f"{sim.stats.finished} finished")

        # right-size the pool memory from the traffic peak, then gate it
        cap = min_capacity_mib(sim.trace.peak_needed()) * MIB
        dur, occ = sim.trace.occupancy_series(sim.total_time, use="needed")
        c = compare(dur, occ, capacity=cap, banks=8,
                    n_reads=sim.bundle.access.n_reads("kv"),
                    n_writes=sim.bundle.access.n_writes("kv"))
        print(f"C={cap//MIB} MiB, B=8: {c.format()}")

        # Stage II consumes the traffic trace exactly like a Stage-I trace
        table = sweep(sim.bundle, mem_name="kv",
                      capacities_mib=[cap // MIB], banks=(1, 4, 8, 16))
        print(table.format())


if __name__ == "__main__":
    main()
