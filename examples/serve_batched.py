"""Batched serving driver: prefill a batch of prompts, decode with a static
KV cache, report prefill latency and decode tokens/s. Uses the same
prefill/decode_step functions the decode_32k / long_500k dry-run cells lower.

Run:  PYTHONPATH=src python examples/serve_batched.py [--arch tinyllama-1.1b]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import build_model
from repro.serve import BatchedServer, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch))
    model = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = model.init(jax.random.PRNGKey(0))

    max_len = args.prompt_len + args.new_tokens + 8
    if cfg.local_window:
        max_len = max(max_len, cfg.local_window)
    srv = BatchedServer(model, params, ServeConfig(
        max_len=max_len, max_new_tokens=args.new_tokens,
        temperature=args.temperature))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    batch = {"tokens": jnp.asarray(prompts)}
    if cfg.frontend is not None:
        batch["prefix_embeds"] = jnp.zeros(
            (args.batch, cfg.frontend.num_prefix_tokens, cfg.d_model),
            jnp.float32)

    res = srv.generate(batch)
    st = res["stats"]
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.new_tokens}")
    print(f"prefill: {st.prefill_s*1e3:.1f} ms "
          f"({args.batch*args.prompt_len/st.prefill_s:.0f} tok/s)")
    print(f"decode:  {st.decode_s*1e3:.1f} ms "
          f"({st.decode_tokens_per_s:.0f} tok/s)")
    print(f"first generated rows:\n{res['tokens'][:2]}")


if __name__ == "__main__":
    main()
