"""Quantized-KV serving walkthrough: fp32 vs int8 vs fp8 page pools.

Runs the SAME request stream through three `PagedContinuousBatcher`
instances that differ only in `kv_dtype`, then shows every link in the
accuracy-vs-energy chain:

  1. bytes/page per kv_dtype (`serve.paged.page_bytes`): int8 carries a
     4-byte float32 scale per (page, kv_head, row), fp8-E4M3 is scale-free
     at exactly 1 byte/element;
  2. accuracy: max-abs logit error and greedy-token agreement of the
     quantized rollouts vs the fp32 batcher (`collect_logits=True`);
  3. telemetry: the `serve.paged.kv_bytes_physical` gauge and the
     `quant.dequant_pages` counter, live from the enabled registry;
  4. Stage II: each batcher's byte-accurate occupancy trace swept at the
     SAME capacity (sized to the fp32 peak) — the smaller quantized pages
     leave more banks idle, which power gating converts into energy.

Run:  PYTHONPATH=src python examples/quant_serving.py [--arch tinyllama-1.1b]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import build_model
from repro.obs.telemetry import Telemetry
from repro.serve import PagedContinuousBatcher, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--page-size", type=int, default=8)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch))
    model = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size,
                            (args.prompt_len,)).astype(np.int32)
               for _ in range(args.requests)]

    results = {}
    for dt in ("fp32", "int8", "fp8"):
        tel = Telemetry(enabled=True)
        cb = PagedContinuousBatcher(
            model, params, num_slots=args.slots, page_size=args.page_size,
            num_pages=128, chunk_steps=4, attn_backend="ref", kv_dtype=dt,
            collect_logits=True, telemetry=tel)
        for i, p in enumerate(prompts):
            cb.submit(Request(rid=i, tokens=p,
                              max_new_tokens=args.new_tokens))
        done = cb.run()
        results[dt] = {
            "page_bytes": cb.page_bytes,
            "tokens": {r.rid: list(map(int, r.tokens)) for r in done},
            "logits": {r.rid: np.stack(r.logits) for r in done},
            "bundle": cb.occupancy_bundle(),
            "kv_phys": tel.gauge("serve.paged.kv_bytes_physical").max_value,
            "dequants": tel.counter("quant.dequant_pages").value,
        }

    # ---- bytes + accuracy -----------------------------------------------
    fp32 = results["fp32"]
    print(f"quant-serve: {args.requests} requests x {args.new_tokens} new "
          f"tokens on {cfg.name}")
    print(f"\n{'kv_dtype':>8} {'B/page':>7} {'vs fp32':>8} "
          f"{'logit_err':>10} {'tokens':>7} {'dequants':>9}")
    for dt in ("fp32", "int8", "fp8"):
        r = results[dt]
        err = max(float(np.abs(r["logits"][i] - fp32["logits"][i]).max())
                  for i in fp32["logits"])
        match = all(r["tokens"][i] == fp32["tokens"][i]
                    for i in fp32["tokens"])
        print(f"{dt:>8} {r['page_bytes']:>7} "
              f"{fp32['page_bytes'] / r['page_bytes']:>7.2f}x "
              f"{err:>10.2e} {'exact' if match else 'DIFF':>7} "
              f"{r['dequants']:>9}")
        if dt != "fp32":
            assert match, f"{dt} greedy tokens diverged from fp32"

    # ---- Stage II: gate the fp32-peak-sized KV SRAM against each trace --
    # Capacity is fixed at what the fp32 cache needs; the quantized traces
    # occupy proportionally fewer bytes of it, so more banks sit idle and
    # power gating converts the gap into energy.
    from repro.core.candidates import evaluate_candidates, make_grid
    cap = max(results["fp32"]["bundle"].traces["kv"].peak_needed(), 1)
    cands = make_grid([cap], [8], alphas=(1.0,))
    print(f"\n# Stage II: fp32-peak-sized KV SRAM (C={cap} B, B=8) gated "
          f"against each dtype's byte-accurate trace")
    print(f"{'kv_dtype':>8} {'peak_KiB':>9} {'E[mJ]':>9} {'vs fp32':>8}")
    e_fp32 = None
    for dt in ("fp32", "int8", "fp8"):
        b = results[dt]["bundle"]
        tr = b.traces["kv"]
        dur, occ = tr.occupancy_series(b.total_time, use="needed")
        e = evaluate_candidates(dur, occ, cands, n_reads=b.access.n_reads("kv"),
                                n_writes=b.access.n_writes("kv")).e_total[0]
        e_fp32 = e if e_fp32 is None else e_fp32
        print(f"{dt:>8} {tr.peak_needed() // 1024:>9} {e * 1e3:>9.3f} "
              f"{(1 - e / e_fp32) * 100:>+7.1f}%")
    print("\nsmaller pages -> lower occupancy at the same capacity -> more "
          "gate-eligible banks: the 'vs fp32' column is the extra gating "
          "energy the quantized KV cache unlocks.")


if __name__ == "__main__":
    main()
