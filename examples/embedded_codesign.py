"""Embedded co-design sweep: for any architecture in the zoo, find the minimum
SRAM (Stage-I sizing loop), then recommend a banking + power-gating
configuration (Stage II) — the paper's methodology as a framework feature.

Run:  PYTHONPATH=src python examples/embedded_codesign.py --arch olmoe-1b-7b
      PYTHONPATH=src python examples/embedded_codesign.py --all
"""
import argparse

from repro.configs import ASSIGNED_ARCHS, get_arch
from repro.core.explorer import min_capacity_mib, sweep
from repro.core.workload import build_graph
from repro.sim.accelerator import baseline_accelerator
from repro.sim.engine import find_min_sram, simulate

MIB = 2**20


def codesign(arch: str, M: int = 2048) -> str:
    cfg = get_arch(arch)
    graph = build_graph(cfg, M=M, subops=4)
    mib, sim = find_min_sram(graph, baseline_accelerator(128),
                             lo_mib=16, hi_mib=256, step_mib=16)
    trace = sim.traces["sram"]
    table = sweep(sim, capacities_mib=[mib],
                  banks=(1, 2, 4, 8, 16, 32))
    best = table.best()
    return (f"{arch:24s} minSRAM={mib:4d}MiB "
            f"peak={trace.peak_needed()/MIB:6.1f}MiB "
            f"t={sim.total_time*1e3:7.1f}ms util={sim.pe_utilization*100:4.1f}% "
            f"-> B={best.banks:2d} banks: {best.delta_e_pct:+.1f}% energy, "
            f"{best.delta_a_pct:+.1f}% area")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--seq", type=int, default=2048)
    args = ap.parse_args()

    archs = list(ASSIGNED_ARCHS) if args.all else [args.arch]
    print(f"TRAPTI co-design at M={args.seq} (alpha=0.9, conservative gating)")
    for a in archs:
        print(codesign(a, args.seq))


if __name__ == "__main__":
    main()
