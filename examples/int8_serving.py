"""int8 quantized serving path: the paper's accelerator computes in 8-bit
operands; on TPU the analogous serving optimization is int8 weights +
activations through the MXU (repro.kernels.int8_matmul), halving weight HBM
traffic — exactly the decode roofline's mandatory-bytes term.

This demo quantizes a reduced model's FFN weights and compares the quantized
forward against fp32: per-layer error, end-to-end logit error, and top-1
agreement.

Run:  PYTHONPATH=src python examples/int8_serving.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.kernels.int8_matmul import (int8_matmul, quantize_cols,
                                       quantize_rows)
from repro.models import build_model, concrete_batch


def quantized_ffn(p_ffn, x):
    """SwiGLU with every matmul through the int8 kernel (ref backend on CPU,
    Pallas on TPU)."""
    def qmm(x2d, w):
        xq, sx = quantize_rows(x2d)
        wq, sw = quantize_cols(w)
        return int8_matmul(xq, wq, sx, sw)

    B, S, D = x.shape
    x2 = x.reshape(B * S, D)
    g = jax.nn.silu(qmm(x2, p_ffn["w_gate"]))
    u = qmm(x2, p_ffn["w_up"])
    out = qmm((g * u).astype(x.dtype), p_ffn["w_down"])
    return out.reshape(B, S, -1)


def main() -> None:
    cfg = reduced(get_arch("dsr1d-qwen-1.5b"))
    model = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    batch = concrete_batch(cfg, "prefill", 2, 32)

    # --- per-layer FFN comparison -------------------------------------------
    from repro.models.ffn import apply_ffn
    slot = jax.tree.map(lambda a: a[0], params["blocks"][0])   # layer 0
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    fp = apply_ffn(cfg, slot["ffn"], x)
    q8 = quantized_ffn(slot["ffn"], x)
    rel = float(jnp.linalg.norm(q8 - fp) / jnp.linalg.norm(fp))
    print(f"FFN int8 vs fp32 relative L2 error: {rel:.4f}")

    # --- end-to-end logits: swap all FFN weights with fake-quantized copies --
    def fake_quant(w):
        if w.ndim < 2 or not jnp.issubdtype(w.dtype, jnp.floating):
            return w
        amax = jnp.max(jnp.abs(w), axis=-2, keepdims=True)
        s = jnp.maximum(amax, 1e-8) / 127.0
        return jnp.clip(jnp.round(w / s), -127, 127) * s

    qparams = jax.tree.map(fake_quant, params)
    logits_fp, _ = model.prefill(params, batch, cache_len=48)
    logits_q8, _ = model.prefill(qparams, batch, cache_len=48)
    err = float(jnp.max(jnp.abs(logits_q8 - logits_fp)))
    agree = float(jnp.mean(jnp.argmax(logits_q8, -1)
                           == jnp.argmax(logits_fp, -1)))
    print(f"end-to-end (all weights int8-fake-quantized): "
          f"max|dlogit|={err:.3f}  top-1 agreement={agree*100:.0f}%")

    # weight-bytes saving for the decode roofline
    n = cfg.param_count()
    print(f"weight HBM bytes: bf16 {2*n/1e6:.1f} MB -> int8 {n/1e6:.1f} MB "
          f"(decode mandatory-bytes term halves)")


if __name__ == "__main__":
    main()
