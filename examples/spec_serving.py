"""Speculative-decoding serving walkthrough: draft/target on one page pool
-> batched k-token verification -> rollback-by-page-truncation -> the
acceptance-rate-dependent occupancy signature Stage II prices.

The pipeline this demonstrates end to end:

  1. `PagedContinuousBatcher(speculate_k=k)` runs draft-model speculation
     on the paged path: a self-speculation draft (every `skip`-th layer of
     the target, same weights) proposes k tokens per round, and the target
     scores all k+1 candidate rows in ONE batched `paged_gqa_verify` call
     instead of k+1 sequential decode steps;
  2. acceptance keeps the longest drafted prefix that matches the target's
     argmax (plus the target's own bonus token), so the emitted stream is
     *bit-identical* to the non-speculative loop — the draft only changes
     how fast tokens arrive, never which tokens;
  3. both KV lanes (target + draft) burst to the verify window each round,
     then `truncate_rows` rolls the rejected suffix back through the same
     refcounted allocator COW and eviction use — the occupancy trace gets
     a per-round sawtooth whose amplitude is the rejection rate;
  4. the model-free `simulate_spec_traffic` sweeps that signature across
     acceptance rates, and `core.explorer.sweep` prices the banking/gating
     consequences.

Run:  PYTHONPATH=src python examples/spec_serving.py [--arch tinyllama-1.1b]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.core.explorer import MIB, sweep
from repro.models import build_model
from repro.serve import PagedContinuousBatcher, Request
from repro.traffic import generate, simulate_spec_traffic
from repro.traffic.generators import LengthModel


def run(model, params, prompts, new_tokens, **kw):
    cb = PagedContinuousBatcher(model, params, num_slots=2, page_size=8,
                                num_pages=96, max_pages_per_slot=10,
                                chunk_steps=4, attn_backend="ref", **kw)
    for i, p in enumerate(prompts):
        cb.submit(Request(rid=i, tokens=p, max_new_tokens=new_tokens))
    done = cb.run()
    return {r.rid: list(r.output) for r in done}, cb


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--speculate", type=int, default=3)
    ap.add_argument("--new-tokens", type=int, default=14)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch), layers=args.layers)
    model = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n) for n in (9, 13, 6)]

    # ---- the acceptance guarantee, live ---------------------------------
    ref, _ = run(model, params, prompts, args.new_tokens)
    got, cb = run(model, params, prompts, args.new_tokens,
                  speculate_k=args.speculate)
    st = cb.stats
    k = args.speculate
    print(f"speculate_k={k} (self-speculation, skip=2: "
          f"{args.layers // 2}/{args.layers} layers draft)")
    print(f"bit-identical to non-speculative loop: {got == ref}")
    print(f"  {st.spec_rounds} verify rounds, {st.drafted_tokens} drafted, "
          f"{st.accepted_tokens} tokens accepted "
          f"({st.accepted_tokens / max(st.spec_rounds, 1):.2f}/{k + 1} per "
          f"round), {st.rolled_back_pages} pages rolled back by truncation")
    steps_saved = st.accepted_tokens - st.spec_rounds
    print(f"  sequential target decode steps avoided: {steps_saved} "
          f"({steps_saved / max(st.accepted_tokens, 1):.0%} of tokens)")

    # ---- acceptance rate -> occupancy signature -------------------------
    # the model-free simulator sweeps what the serving path just produced:
    # higher rejection = taller per-round sawtooth (burst to the verify
    # window, rollback to the accepted context) on BOTH page lanes
    full = get_arch(args.arch)
    lengths = LengthModel(max_len=512)
    reqs = generate("poisson", 6.0, 10.0, seed=0, lengths=lengths)
    print(f"\nmodel-free sweep: {len(reqs)} requests, k=4, draft=0.5x "
          f"({full.name})")
    print(f"  {'accept':>6} {'tok/round':>9} {'rolled-back':>11} "
          f"{'peak[MiB]':>9} {'mean[MiB]':>9}")
    sims = {}
    for acc in (0.3, 0.6, 0.9):
        sim = simulate_spec_traffic(full, reqs, num_slots=8, max_len=512,
                                    spec_k=4, acceptance=acc,
                                    draft_kv_frac=0.5, seed=0)
        sims[acc] = sim
        s = sim.stats
        tr = sim.trace
        print(f"  {acc:>6.1f} "
              f"{s.accepted_tokens / max(s.spec_rounds, 1):>9.2f} "
              f"{s.rolled_back_pages:>11} "
              f"{tr.peak_needed() / MIB:>9.1f} "
              f"{tr.time_weighted_mean(sim.total_time) / MIB:>9.1f}")

    # ---- Stage II prices the signature ----------------------------------
    # the sawtooth widens the gap between peak (what capacity must cover)
    # and mean (what leakage actually pays after gating)
    print("\n# Stage-II sweep on the acceptance=0.6 spec trace")
    table = sweep(sims[0.6].bundle, mem_name="kv", capacities_mib=[16, 32],
                  banks=[1, 4, 8, 16])
    print(table.format())


if __name__ == "__main__":
    main()
