"""Energy-attribution walkthrough: mixed-tenant prefix traffic -> streaming
per-bank energy meter -> J/request percentiles + per-tenant split -> exact
check against offline Stage II -> Perfetto bank-state timeline on disk.

The pipeline this demonstrates end to end:

  1. a `chat_sysprompt` workload (tenant groups share system prompts) is
     drawn from the seeded traffic generators and replayed through the
     model-free prefix-sharing simulator with a `BankEnergyMeter`
     attached — every page alloc/free/COW event updates an online
     per-bank active/drowsy/gated state machine for one (C, B, alpha,
     policy) operating point, charging each bank-wake transient and
     retention interval to the request (and tenant) that caused or
     sustained it;
  2. `meter.report()` renders live leakage+switching energy, J/request
     p50/p90/p99, the per-tenant energy split, wake-cause counters
     (admission / decode growth / COW) and gating stall exposure;
  3. the cumulative integral is checked **bit-identical (f64)** against
     the offline reference — `core.gating.evaluate` over the very
     occupancy trace the sim emitted — so the dashboard numbers are the
     paper's Stage-II numbers, streamed;
  4. `export_chrome_trace(meter=...)` writes per-bank state lanes plus
     cumulative-energy and active-bank counter tracks next to the KV
     occupancy track — drop it on https://ui.perfetto.dev and scrub the
     exact timeline the energy was integrated over.

Run:  PYTHONPATH=src python examples/energy_attribution.py [--meter 32,8]
"""
import argparse

import numpy as np

from repro.configs import get_arch
from repro.core.gating import evaluate
from repro.obs import BankEnergyMeter, export_chrome_trace
from repro.traffic.generators import LengthModel, generate_workload
from repro.traffic.occupancy import simulate_prefix_traffic


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dsr1d-qwen-1.5b")
    ap.add_argument("--meter", default="32,8,0.9,conservative",
                    metavar="C,B[,alpha[,policy]]",
                    help="capacity [MiB], banks, target occupancy, policy")
    ap.add_argument("--rate", type=float, default=6.0)
    ap.add_argument("--horizon", type=float, default=8.0)
    ap.add_argument("--sharing", type=int, default=4)
    ap.add_argument("--prefix-len", type=int, default=128)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="energy_timeline.json")
    args = ap.parse_args()

    cfg = get_arch(args.arch)

    # ---- mixed-tenant workload + metered model-free serve ---------------
    lengths = LengthModel(max_len=args.max_len)
    reqs = generate_workload("chat_sysprompt", rate=args.rate,
                             horizon_s=args.horizon, seed=args.seed,
                             lengths=lengths, prefix_len=args.prefix_len,
                             sharing=args.sharing)
    meter = BankEnergyMeter.from_spec(args.meter)
    sim = simulate_prefix_traffic(cfg, reqs, num_slots=4,
                                  max_len=args.max_len, seed=args.seed,
                                  meter=meter)
    n_tenants = len({r.prefix_id for r in reqs})
    print(f"{args.arch}: {sim.stats.finished}/{len(reqs)} requests from "
          f"{n_tenants} tenants, {sim.stats.prefix_hits} prefix hits, "
          f"{meter.n_events} meter events")

    # ---- streaming report: J/request, per-tenant split, wake causes -----
    tokens_by_rid = {r.rid: r.prompt_len + r.output_len for r in reqs}
    rep = meter.report(sim.total_time, tokens_by_rid=tokens_by_rid)
    print()
    print(rep.format())

    # ---- exactness: streamed integral == offline Stage II (f64) ---------
    dur, occ = sim.trace.occupancy_series(sim.total_time, use="needed")
    ref = evaluate(dur, occ, capacity=meter.capacity, banks=meter.banks,
                   policy=meter.policy, n_reads=0, n_writes=0,
                   char=meter.char)
    got = rep.result
    assert (got.e_leak, got.e_sw, got.n_transitions) == \
        (ref.e_leak, ref.e_sw, ref.n_transitions), "meter drifted offline!"
    print(f"\nexact vs offline gating.evaluate: MATCH (bit-identical f64) — "
          f"E_leak+E_sw = {(got.e_leak + got.e_sw) * 1e3:.4f} mJ over "
          f"{got.n_transitions} bank transitions")

    # conservation: every joule lands on a request, a tenant, or the floor
    req_j = sum(rep.request_j.values())
    ten_j = sum(rep.tenant_j.values())
    assert np.isclose(req_j + rep.floor_j, rep.live_e_j, rtol=1e-9)
    assert np.isclose(ten_j + rep.floor_j, rep.live_e_j, rtol=1e-9)
    print(f"attribution conserves energy: {req_j * 1e3:.4f} mJ on requests "
          f"+ {rep.floor_j * 1e3:.4f} mJ idle floor = total")

    # ---- Perfetto bank-state timeline -----------------------------------
    export_chrome_trace(args.out, traces=sim.bundle.traces.values(),
                        end_time=sim.total_time, meter=meter)
    print(f"\nwrote {args.out} ({meter.banks} bank-state lanes + energy "
          f"counters) — load it at ui.perfetto.dev: bank lanes under "
          f"'sram banks', cumulative J + active banks as counter tracks")


if __name__ == "__main__":
    main()
